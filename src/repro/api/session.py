"""``MinosSession``: the unified ingestion-to-decision facade.

One object owns the whole Minos mechanism — the ``ReferenceLibrary`` (warm
classifier), the device inventory, the shared power budget, and the three
policy axes (objective / actuator / provisioning quantile, all resolvable
by registry name) — and exposes the full job lifecycle:

    session = MinosSession(lib, inventory=inv, budget_w=50_000.0)
    job = session.submit(stream, device=inv[0], chips=256)   # -> JobHandle
    job.feed(chunks)            # incremental telemetry; early CapDecision
    job.decision()              # the (possibly finalized) cap decision
    job.plan()                  # its cached power reservation
    job.retire()                # release budget; repack WITHOUT reclassify
    report = session.run()      # drain attached streams -> SessionReport

Decisions are byte-identical to the direct ``OnlineCapController`` /
``FleetCapController`` paths (pinned in ``tests/test_api.py``): the facade
routes every chunk through exactly the same per-job builder + controller
machinery, device-frame normalization included.  Jobs may arrive *and
retire* at any point; retirement and budget changes re-pack from cached
``JobPlan``s and never re-classify.

``MinosSession.from_config(dict | json)`` constructs a session declaratively
— library path, device counts + variability, budget, and the three policy
names — so a deployment is one JSON document away from a running session.
"""
from __future__ import annotations

import difflib
import json
import math
import os
import warnings
from contextlib import nullcontext

from repro.api.registry import ACTUATORS, OBJECTIVES, QUANTILES
from repro.core.algorithm1 import resolve_objective
from repro.fleet.controller import FleetCapController, FleetEvent, \
    FleetJob, RepackTrail
from repro.fleet.inventory import DEGRADED, FAILED, DeviceInstance, \
    DeviceInventory, VariabilityModel
from repro.fleet.mux import FleetTelemetryMux
from repro.fleet.records import device_from_record, device_record, \
    mesh_from_record, mesh_record, meta_from_record, meta_record
from repro.ft.fleetwatch import FleetStragglerAdapter
from repro.ft.heartbeat import StragglerMonitor
from repro.pipeline.builder import PartialProfile
from repro.pipeline.online import CapDecision
from repro.sched.dvfs import FrequencyActuator
from repro.sched.power_sched import JobPlan
from repro.store import NoStoreError, SessionStore, StoreError, kinds
from repro.telemetry.kernel_stream import KernelStream
from repro.telemetry.simulator import TelemetryChunk, TraceMeta, \
    stream_telemetry

from repro.api.results import SessionReport, from_dict, to_dict

_GATE_KEYS = ("min_confidence", "min_fraction", "min_spike_samples")
_STRAGGLER_KEYS = ("window", "k", "min_samples")
_CONFIG_KEYS = frozenset({"library", "devices", "variability", "seed",
                          "objective", "actuator", "quantile", "budget_w",
                          "budget_fraction_of_nameplate", "gates",
                          "stragglers", "store", "discovery"})


class JobHandle:
    """Live handle on one submitted job (create via ``MinosSession.submit``).

    The handle stays valid after retirement: ``decision()``/``plan()`` keep
    returning the cached artifacts; only feeding is rejected."""

    def __init__(self, session: "MinosSession", job: FleetJob,
                 meta: TraceMeta, chunks=None):
        self._session = session
        self._job = job
        self.meta = meta
        self._chunks = chunks        # attached telemetry iterator (optional)
        self.retired = False

    # -- introspection ---------------------------------------------------
    @property
    def job_id(self) -> str:
        return self._job.job_id

    @property
    def device(self) -> DeviceInstance:
        return self._job.device

    @property
    def decided(self) -> bool:
        return self._job.decision is not None

    @property
    def actuator(self):
        """The job's DVFS actuator (plugin-chosen; ``None`` = no actuation)."""
        return self._job.actuator

    @property
    def fraction(self) -> float:
        """Fraction of the expected trace ingested so far."""
        return self._job.builder.fraction

    def snapshot(self) -> PartialProfile:
        """A valid partial profile over everything fed so far (pure)."""
        return self._job.builder.snapshot()

    def profile(self) -> PartialProfile:
        """Finalize the job's builder and return the completed profile.
        After this the job accepts no more telemetry."""
        return self._job.builder.finalize()

    # -- lifecycle -------------------------------------------------------
    def feed(self, chunks) -> CapDecision | None:
        """Ingest telemetry: one ``TelemetryChunk`` or an iterable of them
        (in stream order).  Returns the job's ``CapDecision`` the moment a
        chunk tips its confidence gate — which also re-packs the session —
        else ``None``.  Chunks after a decision are dropped (or kept, with
        ``profile_to_completion=True`` at submit)."""
        self._check_live()
        if isinstance(chunks, TelemetryChunk):
            chunks = (chunks,)
        decision = None
        for chunk in chunks:
            d = self._session._fleet.ingest_chunk(self.job_id, chunk)
            decision = decision or d
        return decision

    def run(self, stop_early: bool = True) -> CapDecision:
        """Pump the attached telemetry stream: with ``stop_early`` (default)
        the pull stops at the first confident decision — the paper's
        profiling-cost saving — else the whole stream is consumed.  Falls
        back to the finalize decision at stream end."""
        self._check_live()
        if self._chunks is None:
            raise ValueError(f"job {self.job_id!r} has no attached stream; "
                             f"feed() it chunks instead")
        chunks, self._chunks = self._chunks, None
        for chunk in chunks:
            decision = self.feed(chunk)
            if decision is not None and stop_early:
                return decision
        return self.decision()

    def decision(self, finalize: bool = True) -> CapDecision | None:
        """The job's cap decision.  If none has fired yet and ``finalize``
        is set (default), decide now from everything ingested so far — the
        batch-equivalent decision; with ``finalize=False`` returns ``None``
        until a decision lands.  A handle retired before any decision has
        nothing cached and returns ``None``."""
        if self._job.decision is not None or not finalize or self.retired:
            return self._job.decision
        return self._session._fleet.finalize_job(self.job_id)

    def plan(self) -> JobPlan | None:
        """The job's cached power reservation (built once, from the
        decision's Algorithm 1 selection); ``None`` before a decision."""
        return self._job.plan

    def reprofile(self, source, freq: float = 1.0, **telemetry_kw) -> None:
        """Restart this job's profiling run — the recovery step after a
        mid-profile device failure migrated it (its partial trace died with
        the old device).  ``source`` is a ``KernelStream`` (profiled on the
        job's *current* device), a ``(meta, chunks)`` pair, or a bare
        ``TraceMeta``; fresh chunks attach to the handle for ``run()`` /
        the session drain.  Only undecided jobs can re-profile."""
        self._check_live()
        if isinstance(source, KernelStream):
            meta, chunks = stream_telemetry(
                source, freq, self.device.power_model(),
                device_id=self.device.device_id, **telemetry_kw)
        elif isinstance(source, TraceMeta):
            meta, chunks = source, None
        elif isinstance(source, tuple) and len(source) == 2 \
                and isinstance(source[0], TraceMeta):
            meta, chunks = source
        else:
            raise TypeError(f"reprofile() takes a KernelStream, a TraceMeta,"
                            f" or a (meta, chunks) pair, got "
                            f"{type(source).__name__}")
        self._session._fleet.restart_profile(self.job_id, meta)
        self.meta = meta
        self._chunks = chunks

    def retire(self) -> JobPlan | None:
        """Retire this job (see ``MinosSession.retire``)."""
        return self._session.retire(self.job_id)

    def _take_chunks(self):
        """Detach and return the pending stream (None if already consumed)."""
        chunks, self._chunks = self._chunks, None
        return chunks

    def _check_live(self) -> None:
        if self.retired:
            raise ValueError(f"job {self.job_id!r} is retired")


class MinosSession:
    """The session facade over the streaming pipeline + fleet layers."""

    def __init__(self, references, *, inventory: DeviceInventory | None = None,
                 budget_w: float = math.inf, objective="powercentric",
                 actuator="sim", quantile="p99",
                 min_confidence: float = 0.3, min_fraction: float = 0.1,
                 min_spike_samples: int = 50, stragglers=None, store=None,
                 discovery=None):
        """``references`` is a ``ReferenceLibrary`` (preferred: warm
        classifier), a ``MinosClassifier``, or a profile list.  ``objective``
        / ``actuator`` / ``quantile`` accept registry names (see
        ``repro.api.registry``) or policy objects; gate thresholds match the
        direct ``OnlineCapController`` defaults.

        ``stragglers`` opts into proactive degrade-and-drain: pass a
        ``ft.StragglerMonitor`` (or a prebuilt ``FleetStragglerAdapter``, or
        ``True`` for monitor defaults) and the fleet flags devices whose
        telemetry cadence falls behind, migrating their decided jobs to
        healthy silicon without a single re-classification.

        ``store`` opts into durability: pass a directory path (or a
        prebuilt ``repro.store.SessionStore``) and every admit, decision,
        plan, retirement, budget change, and device-health transition is
        journaled write-ahead — ``MinosSession.resume(path)`` reconstructs
        the session after a crash with zero classifier calls.  Without a
        store every code path is byte-identical to the store-less session.

        ``discovery`` opts into online class discovery: pass ``True``
        (defaults), a knobs dict (see ``repro.discovery.DISCOVERY_KEYS``),
        or a prebuilt ``DiscoveryController`` — finalized low-margin
        decisions then quarantine their profiles, periodic re-clustering
        mints candidate classes, and shadow-vetted promotions publish a new
        library version the fleet adopts atomically between ticks
        (``references`` must be a ``ReferenceLibrary``).  Set
        ``session.discovery.profiler`` to a full-profile callable to enable
        promotion.  Without a discovery key every code path is
        byte-identical to the pre-discovery session."""
        self.library = references        # whatever was handed in (may be lib)
        self.inventory = inventory
        self._objective = self._resolve_objective(objective)
        self._quantile = QUANTILES.get(quantile) \
            if isinstance(quantile, str) else quantile
        self._fleet = FleetCapController(
            references, budget_w=budget_w, objective=self._objective,
            provision_quantile=self._quantile,
            min_confidence=min_confidence, min_fraction=min_fraction,
            min_spike_samples=min_spike_samples,
            actuator_factory=self._resolve_actuator(actuator),
            inventory=inventory,
            straggler_adapter=self._resolve_stragglers(stragglers))
        self._handles: dict[str, JobHandle] = {}
        self._retired: dict[str, CapDecision | None] = {}
        self._rr = 0                     # round-robin cursor over inventory
        self._default_device: DeviceInstance | None = None
        self._actuator_name = actuator if isinstance(actuator, str) else None
        self._library_path = None        # set when built via from_config
        self._store: SessionStore | None = None
        self._discovery = self._init_discovery(discovery, references)
        if self._discovery is not None:
            self._fleet.set_discovery(self._discovery)
        if store is not None:
            self._init_store(store)

    # -- plugin resolution ----------------------------------------------
    @staticmethod
    def _resolve_objective(objective):
        if isinstance(objective, str):
            objective = OBJECTIVES.get(objective)
        return resolve_objective(objective)

    @staticmethod
    def _resolve_actuator(actuator):
        if actuator is None:
            return None
        if isinstance(actuator, str):
            return ACTUATORS.get(actuator)
        if isinstance(actuator, FrequencyActuator):
            return lambda device=None: actuator   # one shared instance
        if callable(actuator):
            return actuator
        raise ValueError(f"actuator must be a registry name, factory, or "
                         f"FrequencyActuator, got {actuator!r}")

    @staticmethod
    def _resolve_stragglers(stragglers):
        if stragglers is None or stragglers is False:
            return None
        if stragglers is True:
            return FleetStragglerAdapter()
        if isinstance(stragglers, FleetStragglerAdapter):
            return stragglers
        if isinstance(stragglers, StragglerMonitor):
            return FleetStragglerAdapter(stragglers)
        raise ValueError(f"stragglers must be True, a StragglerMonitor, or "
                         f"a FleetStragglerAdapter, got {stragglers!r}")

    def _init_discovery(self, discovery, references):
        """Resolve the ``discovery`` option into a ``DiscoveryController``
        (or ``None`` — the inert default: no discovery attribute is touched
        anywhere on the hot paths)."""
        if discovery is None or discovery is False:
            return None
        from repro.discovery import DISCOVERY_KEYS, DiscoveryController
        if isinstance(discovery, DiscoveryController):
            return discovery
        if discovery is True:
            knobs = {}
        elif isinstance(discovery, dict):
            bad = set(discovery) - set(DISCOVERY_KEYS)
            if bad:
                raise ValueError(f"unknown discovery keys {sorted(bad)}; "
                                 f"recognized: {list(DISCOVERY_KEYS)}")
            knobs = dict(discovery)
        else:
            raise ValueError(f"discovery must be True, a knobs dict, or a "
                             f"DiscoveryController, got {discovery!r}")
        from repro.pipeline.library import ReferenceLibrary
        if not isinstance(references, ReferenceLibrary):
            raise ValueError(
                "discovery needs the session references to be a "
                "ReferenceLibrary (promotions version its membership); got "
                f"{type(references).__name__}")
        return DiscoveryController(references, objective=self._objective,
                                   **knobs)

    # -- declarative construction ----------------------------------------
    @classmethod
    def from_config(cls, config, references=None) -> "MinosSession":
        """Build a session from a config dict, a JSON string, or a path to a
        JSON file.  Recognized keys (all optional unless noted):

          * ``library``       — reference-store directory (required unless a
            ``references`` object is passed in);
          * ``devices``       — chip-model -> count (or a bare int of
            nominal v5e chips); ``variability`` — sigma dict (``{}`` =
            published defaults), ``"none"``/omitted = nominal chips;
            ``seed`` — inventory RNG seed;
          * ``objective`` / ``actuator`` / ``quantile`` — registry names;
          * ``budget_w`` — shared power budget in watts, or
            ``budget_fraction_of_nameplate`` — fraction of the inventory's
            total per-device nameplate TDP (requires ``devices``);
          * ``gates`` — ``min_confidence`` / ``min_fraction`` /
            ``min_spike_samples`` overrides;
          * ``stragglers`` — ``true`` (monitor defaults) or a
            ``window``/``k``/``min_samples`` dict: proactive
            degrade-and-drain of devices whose telemetry cadence lags;
          * ``store`` — durable-session directory (must be fresh): every
            mutation is journaled write-ahead so a crashed session can be
            reconstructed with ``MinosSession.resume(path)``;
          * ``discovery`` — ``true`` (defaults) or a knobs dict
            (``quarantine_below`` / ``min_cluster`` / ``cluster_distance``
            / ``promote_agreement`` / ``recluster_every`` / ``capacity`` /
            ``min_confidence_gain`` / ``bin_size``): online class discovery
            — low-margin decisions quarantine, re-clustering mints
            candidates, shadow-vetted promotions publish new library
            versions (requires a ``ReferenceLibrary``; attach a profiler
            via ``session.discovery.profiler`` to enable promotion).
        """
        if isinstance(config, (str, os.PathLike)):
            text = str(config)
            if not text.lstrip().startswith("{"):
                with open(text) as f:
                    text = f.read()
            config = json.loads(text)
        if not isinstance(config, dict):
            raise ValueError(f"config must be a dict, JSON text, or a path, "
                             f"got {type(config).__name__}")
        unknown = set(config) - _CONFIG_KEYS
        if unknown:
            labels = []
            for key in sorted(unknown):
                close = difflib.get_close_matches(key, _CONFIG_KEYS, n=1)
                labels.append(f"{key!r} (did you mean {close[0]!r}?)"
                              if close else repr(key))
            raise ValueError(f"unknown config keys {', '.join(labels)}; "
                             f"recognized: {sorted(_CONFIG_KEYS)}")

        if references is None:
            if "library" not in config:
                raise ValueError("config needs a 'library' store path "
                                 "(or pass a references object)")
            from repro.pipeline.library import ReferenceLibrary
            references = ReferenceLibrary.load(config["library"])

        inventory = None
        if "devices" in config:
            var = config.get("variability")
            if var is None or var == "none":
                var = VariabilityModel.none()
            elif isinstance(var, dict):
                var = VariabilityModel(**var)
            elif not isinstance(var, VariabilityModel):
                raise ValueError(f"variability must be a sigma dict or "
                                 f"'none', got {var!r}")
            inventory = DeviceInventory.generate(
                config["devices"], var, seed=int(config.get("seed", 0)))

        if "budget_w" in config and "budget_fraction_of_nameplate" in config:
            raise ValueError("give budget_w or budget_fraction_of_nameplate,"
                             " not both")
        budget_w = math.inf
        if "budget_w" in config:
            budget_w = float(config["budget_w"])
        elif "budget_fraction_of_nameplate" in config:
            if inventory is None:
                raise ValueError("budget_fraction_of_nameplate needs "
                                 "'devices'")
            budget_w = float(config["budget_fraction_of_nameplate"]) \
                * inventory.nameplate_w

        gates = dict(config.get("gates", {}))
        bad = set(gates) - set(_GATE_KEYS)
        if bad:
            raise ValueError(f"unknown gate keys {sorted(bad)}; "
                             f"recognized: {list(_GATE_KEYS)}")

        stragglers = config.get("stragglers")
        if isinstance(stragglers, dict):
            bad = set(stragglers) - set(_STRAGGLER_KEYS)
            if bad:
                raise ValueError(f"unknown straggler keys {sorted(bad)}; "
                                 f"recognized: {list(_STRAGGLER_KEYS)}")
            stragglers = StragglerMonitor(**stragglers)
        elif stragglers not in (None, True, False):
            raise ValueError(f"stragglers must be true or a monitor-params "
                             f"dict, got {stragglers!r}")
        session = cls(references, inventory=inventory, budget_w=budget_w,
                      objective=config.get("objective", "powercentric"),
                      actuator=config.get("actuator", "sim"),
                      quantile=config.get("quantile", "p99"),
                      stragglers=stragglers,
                      discovery=config.get("discovery"), **gates)
        if "library" in config:
            session._library_path = str(config["library"])
        if "store" in config:
            session._init_store(config["store"])
        return session

    # -- durability ------------------------------------------------------
    @classmethod
    def resume(cls, path, references=None, fsync: bool = False) \
            -> "MinosSession":
        """Reconstruct a crashed session from its store directory.

        Loads the latest intact snapshot and replays the journal tail: every
        cached ``CapDecision``/``JobPlan`` and device-health transition is
        re-adopted verbatim — **zero classifier calls**.  Torn journal tails
        are truncated with a warning; a corrupt latest snapshot falls back
        to its predecessor (longer replay).  Jobs that were still profiling
        when the process died lost their in-flight telemetry (chunks are
        not journaled) and come back flagged ``needs_reprofile`` — restart
        them via ``JobHandle.reprofile``.

        ``references`` is only needed when the original session was built
        around an in-memory reference library; sessions created through
        ``from_config({"library": ...})`` reload it from the recorded path.

        Raises ``repro.store.NoStoreError`` when ``path`` holds no store at
        all, ``repro.store.StoreError`` when a store exists but cannot be
        reconstructed."""
        store = SessionStore.open_existing(str(path), encode=to_dict,
                                           fsync=fsync)
        opened = store.open_record()
        if opened is None or opened.kind != kinds.OPEN:
            store.close()
            kind = "no" if opened is None else repr(opened.kind)
            raise StoreError(
                f"session store at {str(path)!r} is corrupt: the journal "
                f"begins with {kind} record instead of the "
                f"session 'open' record, so the session's construction "
                f"facts are lost and it cannot be reconstructed.")
        cfg = opened.data
        if references is None:
            if cfg.get("library") is None:
                store.close()
                raise ValueError(
                    "this store's session was built from an in-memory "
                    "reference library (no 'library' path was recorded); "
                    "pass the references object to resume()")
            from repro.pipeline.library import ReferenceLibrary
            references = ReferenceLibrary.load(cfg["library"])
        inventory = None
        if cfg.get("devices"):
            inventory = DeviceInventory(
                [device_from_record(d) for d in cfg["devices"]])
        session = cls(
            references, inventory=inventory,
            budget_w=from_dict(cfg.get("budget_w", math.inf)),
            objective=cfg.get("objective", "powercentric"),
            actuator=cfg.get("actuator") or "sim",
            quantile=cfg.get("quantile", "p99"),
            stragglers=cls._stragglers_from_record(cfg.get("stragglers")),
            discovery=cfg.get("discovery"),
            **(cfg.get("gates") or {}))
        session._library_path = cfg.get("library")
        state, snap_seq = store.load_snapshot()
        if state is not None:
            session._restore_state(state)
        for rec in store.records(after_seq=snap_seq):
            session._apply_record(rec)
        for job in session._fleet.jobs.values():
            if job.decision is None:
                # the in-flight partial trace died with the process:
                # demand a fresh profiling run (PR 5 migration semantics)
                session._fleet._replace_builder(job)
                job.needs_reprofile = True
            elif job.actuator is not None and job.plan is not None:
                job.actuator.set_cap(job.decision.cap)
        fleet = session._fleet
        if not fleet.repacks \
                and any(j.plan is not None for j in fleet.jobs.values()):
            fleet._repack()
        d = session._discovery
        if d is not None and d.version > 1:
            # re-adopt the promoted library version verbatim: a fresh warm
            # classifier from the replayed membership — pure spike-matrix
            # adoption, zero classifier queries (replayed decisions were
            # re-adopted from the journal, never re-derived)
            fleet.adopt_classifier(d.library)
        session._attach_store(store)
        store.record(kinds.RESUME, last_seq=store.journal.last_seq,
                     snapshot_seq=snap_seq)
        store.flush_snapshot(force=True)
        return session

    @property
    def store(self) -> SessionStore | None:
        """The attached durable session store (``None`` = not durable)."""
        return self._store

    def close(self) -> None:
        """Flush a final snapshot and release the store's file handles (a
        no-op for store-less sessions).  The session object stays usable,
        but further mutations are no longer journaled."""
        if self._store is not None:
            self._store.flush_snapshot(force=True)
            self._store.close()
            self._store = None
            self._fleet.journal = None

    def _init_store(self, store) -> None:
        """Attach a FRESH store and durably pin the session's construction
        facts as its ``open`` record."""
        if not isinstance(store, SessionStore):
            store = SessionStore.create(str(store), encode=to_dict)
        if store.journal.last_seq > 0 or store.recovered_records:
            path = store.path
            store.close()
            raise ValueError(
                f"store at {path!r} already holds a session journal; "
                f"continue it with MinosSession.resume({path!r}) or point "
                f"'store' at a fresh directory")
        self._attach_store(store)
        store.record(kinds.OPEN, **self._open_record())

    def _attach_store(self, store: SessionStore) -> None:
        self._store = store
        store.encode = to_dict           # session payloads are typed results
        store.capture = self._capture_state
        self._fleet.journal = store

    def _open_record(self) -> dict:
        """The construction facts ``resume`` rebuilds the session from.
        Policies are recorded by registry name — custom objective/actuator/
        quantile *objects* are not serializable, so resume falls back to
        the defaults for any axis that was not name-resolved."""
        rec = {
            "objective": self.objective,
            "actuator": self._actuator_name,
            "quantile": self._quantile_name(),
            "budget_w": self._fleet.budget_w,
            "gates": dict(self._fleet._gates),
            "devices": [device_record(d) for d in self.inventory]
                       if self.inventory is not None else None,
            "stragglers": self._straggler_record(
                self._fleet.straggler_adapter),
            "library": self._library_path,
        }
        if self._discovery is not None:
            # key present only when enabled: discovery-less stores keep
            # their pre-discovery open-record bytes (inert-by-default)
            rec["discovery"] = self._discovery.config_record()
        return rec

    def _quantile_name(self):
        q = self._quantile
        return q if isinstance(q, str) or q is None \
            else getattr(q, "name", None)

    @staticmethod
    def _straggler_record(adapter) -> dict | None:
        if adapter is None:
            return None
        monitor = adapter.monitor
        return {"window": monitor.window, "k": monitor.k,
                "min_samples": monitor.min_samples,
                "check_every": adapter.check_every}

    @staticmethod
    def _stragglers_from_record(rec):
        if not rec:
            return None
        return FleetStragglerAdapter(
            StragglerMonitor(window=rec["window"], k=rec["k"],
                             min_samples=rec["min_samples"]),
            check_every=rec.get("check_every", 8))

    def _capture_state(self) -> dict:
        """The full JSON-ready session state for one snapshot: restoring it
        and replaying the journal records past its sequence number is
        equivalent to replaying the whole journal."""
        fleet = self._fleet
        jobs = []
        for job in fleet.jobs.values():
            jobs.append({
                "job_id": job.job_id,
                "device": device_record(job.device),
                "chips": job.chips,
                "meta": meta_record(job.builder.meta),
                "profile_to_completion": job.profile_to_completion,
                "devices": [device_record(d) for d in job.devices],
                "mesh": mesh_record(job.mesh),
                "global_batch": job.global_batch,
                "decision": to_dict(job.decision)
                            if job.decision is not None else None,
                "plan": to_dict(job.plan) if job.plan is not None else None,
                "needs_reprofile": job.needs_reprofile,
            })
        state = {
            "budget_w": to_dict(fleet.budget_w),
            "jobs": jobs,
            "retired": {job_id: to_dict(d) if d is not None else None
                        for job_id, d in self._retired.items()},
            "events": [to_dict(e) for e in fleet.events],
            "device_health": fleet.device_health(),
            "failed_devices": sorted(fleet._failed_devices),
            "repacks": len(fleet.repacks),
            "schedule": to_dict(fleet.repacks[-1]) if fleet.repacks else None,
            "dropped": fleet._dropped,
            "rr": self._rr,
        }
        if self._discovery is not None:
            # key present only when enabled: discovery-less snapshots keep
            # their pre-discovery bytes (inert-by-default)
            state["discovery"] = self._discovery.state_record()
        return state

    def _restore_state(self, state: dict) -> None:
        """Materialize a snapshot: jobs are re-admitted with their recorded
        decisions/plans adopted verbatim (never re-derived), then health is
        applied directly — the consequences a live ``fail_device`` would
        trigger are already part of the snapshot, so no drain logic runs."""
        fleet = self._fleet
        for rec in state["jobs"]:
            self._replay_admit(rec)
            job = fleet.jobs[rec["job_id"]]
            if rec["decision"] is not None:
                job.decision = from_dict(rec["decision"])
            if rec["plan"] is not None:
                # through _set_plan so the incremental packer adopts the
                # restored plan population too
                fleet._set_plan(job, from_dict(rec["plan"]))
            job.needs_reprofile = bool(rec["needs_reprofile"])
        if self.inventory is not None:
            for device_id, health in state["device_health"].items():
                if health == FAILED:
                    self.inventory.mark_failed(device_id)
                elif health == DEGRADED:
                    self.inventory.mark_degraded(device_id)
        fleet._failed_devices = set(state["failed_devices"])
        fleet.budget_w = from_dict(state["budget_w"])
        fleet.events = [from_dict(e) for e in state["events"]]
        fleet._dropped = int(state["dropped"])
        self._rr = int(state["rr"])
        self._retired = {job_id: from_dict(d) if d is not None else None
                         for job_id, d in state["retired"].items()}
        if state["schedule"] is not None:
            # only len() and [-1] are ever observed, so padding with the
            # final schedule preserves both without storing the whole trail
            fleet.repacks = RepackTrail([from_dict(state["schedule"])]
                                        * max(int(state["repacks"]), 1))
        if self._discovery is not None and state.get("discovery") is not None:
            self._discovery.restore(state["discovery"])

    def _replay_admit(self, rec: dict) -> None:
        device = device_from_record(rec["device"])
        meta = meta_from_record(rec["meta"])
        self._fleet.admit(
            device, meta, chips=int(rec["chips"]), job_id=rec["job_id"],
            profile_to_completion=bool(rec["profile_to_completion"]),
            devices=[device_from_record(d) for d in rec["devices"]],
            mesh=mesh_from_record(rec["mesh"]),
            global_batch=rec["global_batch"])
        self._handles[rec["job_id"]] = JobHandle(
            self, self._fleet.jobs[rec["job_id"]], meta, None)

    def _apply_record(self, rec) -> None:
        """Replay one journal record against the live (store-detached)
        session.  Only *causes* replay; consequence ``event`` records are
        informational (the deterministic controller logic regenerates the
        identical events), and ``open``/``resume`` are markers."""
        kind, data = rec.kind, rec.data
        match kind:
            case kinds.OPEN | kinds.EVENT | kinds.RESUME:
                return
            case kinds.ADMIT:
                self._replay_admit(data)
            case kinds.DECISION:
                job = self._fleet.jobs[data["job_id"]]
                self._fleet._decide(job, from_dict(data["decision"]),
                                    plan=from_dict(data["plan"]))
                self._fleet._repack()
            case kinds.RETIRE:
                self.retire(data["job_id"])
            case kinds.BUDGET:
                self._fleet.set_budget(from_dict(data["budget_w"]))
            case kinds.FAIL:
                self._fleet.fail_device(data["device"])
            case kinds.DEGRADE:
                self._fleet.degrade_device(data["device"])
            case kinds.RESTORE:
                self._fleet.restore_device(data["device"])
            case kinds.REPROFILE:
                self._fleet.restart_profile(data["job_id"],
                                            meta_from_record(data["meta"]))
            case kinds.CURSOR:
                self._rr = int(data["rr"])
            case kinds.QUARANTINE | kinds.PROMOTE | kinds.ROLLBACK:
                d = self._discovery
                if d is None:
                    warnings.warn(
                        f"journal record {rec.seq} is a discovery {kind!r} "
                        f"record but the resumed session has no discovery "
                        f"configured; skipping it", RuntimeWarning)
                elif kind == kinds.QUARANTINE:
                    d.admit_record(data["entry"])
                elif kind == kinds.PROMOTE:
                    # verbatim re-adoption of the promoted membership:
                    # rebuilds the profiles from their journaled records and
                    # row-appends them — zero classifier calls (the fleet's
                    # classifier is re-pointed once, after the full replay)
                    d.adopt_promoted(int(data["version"]), data["profiles"],
                                     data["consumed"])
                else:
                    d.rollback()
            case _:
                warnings.warn(f"journal record {rec.seq} has unknown kind "
                              f"{kind!r}; skipping it", RuntimeWarning)

    # -- introspection ---------------------------------------------------
    @property
    def classifier(self):
        """The shared warm ``MinosClassifier`` every job classifies against."""
        return self._fleet.clf

    @property
    def scheduler(self):
        return self._fleet.scheduler

    @property
    def objective(self) -> str:
        return self._objective.name

    @property
    def budget_w(self) -> float:
        return self._fleet.budget_w

    @property
    def jobs(self) -> dict[str, JobHandle]:
        """Live (non-retired) job handles, in submit order."""
        return dict(self._handles)

    def __len__(self) -> int:
        return len(self._handles)

    # -- lifecycle -------------------------------------------------------
    def submit(self, source, device=None, chips: int = 1,
               job_id: str | None = None, profile_to_completion: bool = False,
               freq: float = 1.0, devices=None, mesh=None,
               global_batch: int | None = None, **telemetry_kw) -> JobHandle:
        """Admit a job and return its ``JobHandle``.  ``source`` is one of

          * a ``KernelStream`` — the session profiles it on ``device``'s
            power model via ``stream_telemetry`` (``seed``,
            ``target_duration``, ``chunk_samples``, ... pass through) and
            attaches the chunk stream to the handle (``handle.run()``);
          * a ``(meta, chunks)`` pair from ``stream_telemetry`` — attached
            as-is;
          * a bare ``TraceMeta`` — telemetry arrives via ``handle.feed``.

        ``device`` is a ``DeviceInstance``, a device_id string resolved in
        the session inventory, or ``None`` — the next *healthy* inventory
        device (round-robin), or a nominal reference chip when the session
        has no inventory.  Default ``job_id``s (``"<workload>@<device>"``)
        are de-duplicated with a ``#k`` suffix.

        Multi-chip jobs may span several devices: pass the full span as
        ``devices`` (instances or device_ids; must include ``device``) with
        ``chips`` divided evenly across it, plus an optional ``mesh`` /
        ``global_batch`` — a partial device loss then shrinks the job
        through the elastic re-mesh instead of migrating it wholesale."""
        rr_before = self._rr
        device = self._resolve_device(device)
        if devices is not None:
            devices = tuple(self._resolve_device(d) for d in devices)
        if self._store is not None and self._rr != rr_before:
            # auto-placement advanced the round-robin cursor: journal it
            # (before the admit record) so replayed sessions keep placing
            # later submits on the same devices
            self._store.record(kinds.CURSOR, rr=self._rr)
        meta, chunks = self._parse_source(source, device, freq, telemetry_kw)
        if job_id is None:
            job_id = self._unique_job_id(f"{meta.name}@{device.device_id}")
        job_id = self._fleet.admit(device, meta, chips=chips, job_id=job_id,
                                   profile_to_completion=profile_to_completion,
                                   devices=devices, mesh=mesh,
                                   global_batch=global_batch)
        handle = JobHandle(self, self._fleet.jobs[job_id], meta, chunks)
        self._handles[job_id] = handle
        return handle

    def submit_many(self, sources, device=None, chips=1, job_ids=None,
                    profile_to_completion: bool = False, freq: float = 1.0,
                    **telemetry_kw) -> list[JobHandle]:
        """Bulk admission: admit a whole batch of jobs through one fleet
        call and one coalesced journal flush — the fleet-scale submit path.

        ``sources`` is an iterable of :meth:`submit` sources (a
        ``KernelStream``, a ``(meta, chunks)`` pair, or a bare
        ``TraceMeta``).  ``device`` applies to every job (``None`` =
        round-robin placement over healthy inventory, resolved per job
        exactly as sequential submits would).  ``chips`` is one count for
        all jobs or a per-job sequence; ``job_ids`` an optional per-job
        sequence (auto ids are de-duplicated with the same ``#k`` suffixes
        sequential submits produce).  Returns the handles in batch order.

        Session state, placement, and resume behavior are identical to
        calling ``submit`` once per source; the batch writes one cursor
        record (the final round-robin position) plus all admit records in
        a single buffered store flush.  Multi-device spans (``devices``/
        ``mesh``/``global_batch``) stay on ``submit``."""
        sources = list(sources)
        n = len(sources)
        chips_list = [int(chips)] * n if isinstance(chips, int) \
            else [int(c) for c in chips]
        if len(chips_list) != n:
            raise ValueError(f"chips sequence has {len(chips_list)} entries "
                             f"for {n} sources")
        if job_ids is not None:
            job_ids = list(job_ids)
            if len(job_ids) != n:
                raise ValueError(f"job_ids has {len(job_ids)} entries for "
                                 f"{n} sources")
        rr_before = self._rr
        parsed = []
        for source in sources:
            dev = self._resolve_device(device)
            meta, chunks = self._parse_source(source, dev, freq,
                                              telemetry_kw)
            parsed.append((dev, meta, chunks))
        taken: set[str] = set()
        admissions = []
        for i, (dev, meta, _) in enumerate(parsed):
            jid = job_ids[i] if job_ids is not None else None
            if jid is None:
                jid = self._unique_job_id(f"{meta.name}@{dev.device_id}",
                                          taken)
            taken.add(jid)
            admissions.append(dict(
                device=dev, meta=meta, chips=chips_list[i], job_id=jid,
                profile_to_completion=profile_to_completion))
        ctx = self._store.batch() if self._store is not None \
            else nullcontext()
        with ctx:
            if self._store is not None and self._rr != rr_before:
                # one cursor record for the whole batch: replay lands the
                # round-robin exactly where the sequential loop would
                self._store.record(kinds.CURSOR, rr=self._rr)
            ids = self._fleet.admit_many(admissions)
        handles = []
        for jid, (dev, meta, chunks) in zip(ids, parsed):
            handle = JobHandle(self, self._fleet.jobs[jid], meta, chunks)
            self._handles[jid] = handle
            handles.append(handle)
        return handles

    def _parse_source(self, source, device, freq, telemetry_kw):
        """Normalize a submit source into ``(meta, chunks)``."""
        if isinstance(source, KernelStream):
            return stream_telemetry(
                source, freq, device.power_model(),
                device_id=device.device_id, **telemetry_kw)
        if isinstance(source, TraceMeta):
            if telemetry_kw:
                raise ValueError(f"telemetry options {sorted(telemetry_kw)} "
                                 f"only apply when submitting a KernelStream")
            return source, None
        if isinstance(source, tuple) and len(source) == 2 \
                and isinstance(source[0], TraceMeta):
            if telemetry_kw:
                raise ValueError(f"telemetry options {sorted(telemetry_kw)} "
                                 f"only apply when submitting a KernelStream")
            return source
        raise TypeError(f"submit() takes a KernelStream, a TraceMeta, or "
                        f"a (meta, chunks) pair, got "
                        f"{type(source).__name__}")

    def retire(self, job_id: str) -> JobPlan | None:
        """Retire a job: its telemetry stops counting and its plan leaves
        the packing, releasing its budget share — the survivors re-pack
        from cached plans (never re-classifying).  Returns the retired
        job's plan (``None`` if it never decided).  The handle's cached
        ``decision()``/``plan()`` remain readable."""
        handle = self._handles.pop(job_id, None)
        if handle is None:
            raise KeyError(f"unknown or already-retired job {job_id!r}")
        job = self._fleet.retire(job_id)
        handle.retired = True
        self._retired[job_id] = job.decision
        return job.plan

    def set_budget(self, budget_w: float) -> None:
        """Change the shared power budget mid-session; decided jobs re-pack
        against the new ceiling from their cached plans."""
        self._fleet.set_budget(budget_w)

    # -- fault tolerance -------------------------------------------------
    def fail_device(self, device_id: str) -> list[FleetEvent]:
        """A device died: every affected job migrates to surviving healthy
        devices from its cached decision (**zero classifier calls** — the
        same invariant as retire/set_budget), multi-chip jobs shrink via
        the elastic re-mesh, and the fleet re-packs once.  Needs a session
        inventory.  Returns the failure's events (also in ``report()``)."""
        return self._fleet.fail_device(device_id)

    def degrade_device(self, device_id: str) -> list[FleetEvent]:
        """Mark a device as straggling and proactively drain its decided
        jobs onto healthy silicon (no re-classification).  Jobs still
        profiling on it finish and migrate the moment they decide."""
        return self._fleet.degrade_device(device_id)

    def restore_device(self, device_id: str) -> list[FleetEvent]:
        """Return a failed/degraded device to the healthy placement pool
        (existing placements stay put; the device takes new work again)."""
        return self._fleet.restore_device(device_id)

    @property
    def device_health(self) -> dict[str, str]:
        """device_id -> ``"healthy"``/``"degraded"``/``"failed"`` for the
        session inventory (empty without one)."""
        return self._fleet.device_health()

    @property
    def stragglers(self) -> FleetStragglerAdapter | None:
        """The session's straggler adapter (``None`` unless enabled): read
        ``.degraded()`` for cadence outliers and ``.dead()`` for devices
        that went silent — the latter is advisory; escalate a genuinely
        lost device with ``fail_device`` yourself (silence can also mean
        its jobs finished early)."""
        return self._fleet.straggler_adapter

    # -- online class discovery -------------------------------------------
    @property
    def discovery(self):
        """The session's ``DiscoveryController`` (``None`` unless the
        session was built with a ``discovery`` option).  Set its
        ``.profiler`` to a full-profile callable — e.g.
        ``repro.discovery.stream_profiler`` over the streams a production
        profiling run would target — to enable promotion."""
        return self._discovery

    def _require_discovery(self):
        if self._discovery is None:
            raise ValueError(
                "this session has no discovery configured; construct it "
                "with discovery=True (or a knobs dict)")
        return self._discovery

    def discover(self, force: bool = True) -> dict | None:
        """Run one re-cluster + shadow-evaluate pass over the quarantine
        pool now (``force=False`` honours the ``recluster_every`` cadence),
        and — when at least one candidate passes the shadow gate — promote:
        journal the promotion write-ahead, publish the next library
        version, and atomically re-point the whole fleet at it (zero
        classifier calls on the swap).  Returns a promotion summary dict,
        or ``None`` when nothing promoted."""
        d = self._require_discovery()
        promo = d.propose(force=force)
        if promo is None:
            return None
        return self._adopt_promotion(promo)

    def rollback_discovery(self) -> dict:
        """Revert the last promotion (N-1): journal the rollback, restore
        the previous library version, and re-point the fleet at it.  Note
        that plans built *after* the promotion may reference discovered
        classes the restored library no longer has; re-costing such a plan
        (migration, elastic shrink) will fail — roll back before acting on
        a promotion's decisions, or retire the affected jobs first."""
        d = self._require_discovery()
        if d._previous is None:
            raise ValueError("no previous library version to roll back to")
        if self._store is not None:
            self._store.record(kinds.ROLLBACK, version=d.version - 1)
        d.rollback()
        self._fleet.adopt_classifier(d.library)
        if self._store is not None:
            self._store.flush_snapshot(force=True)
        return {"version": d.version, "classes": d.library.names}

    def _maybe_discover(self) -> None:
        """Between-ticks discovery hook (inert without discovery): runs the
        re-cluster pass only when the quarantine cadence says it is due."""
        d = self._discovery
        if d is None or not d.due():
            return
        promo = d.propose()
        if promo is not None:
            self._adopt_promotion(promo)

    def _adopt_promotion(self, promo) -> dict:
        """Journal (write-ahead) + apply a promotion, then swap the fleet's
        classifier atomically — between ticks, never mid-tick."""
        d = self._discovery
        if self._store is not None:
            self._store.record(kinds.PROMOTE, version=promo.version,
                               profiles=promo.profile_records,
                               consumed=list(promo.consumed))
        d.apply(promo)
        self._fleet.adopt_classifier(d.library)
        if self._store is not None:
            # a promotion is a version boundary: snapshot it immediately so
            # a crash right after resumes from the promoted state directly
            self._store.flush_snapshot(force=True)
        return {"version": d.version,
                "classes": [p.name for p in promo.profiles],
                "consumed": len(promo.consumed),
                "reports": [r.record() for r in promo.reports]}

    def run(self, finalize: bool = True) -> SessionReport:
        """Drain every attached-but-unconsumed telemetry stream through the
        deterministic fleet mux (submit-order interleave), then — with
        ``finalize`` (default) — decide any still-undecided jobs from their
        completed profiles and re-pack once more.  Returns the report."""
        pending = [h for h in self._handles.values()
                   if h._chunks is not None]
        if pending:
            mux = FleetTelemetryMux()
            for h in pending:
                mux.add_job(h.job_id, h.meta, h._take_chunks())
            for batch in mux.ticks():
                self._fleet.ingest_tick(batch)
                self._maybe_discover()       # library swaps between ticks
        if finalize and self._fleet.jobs:
            self._fleet.finalize()
            self._maybe_discover()
        return self.report()

    def report(self) -> SessionReport:
        """The session outcome so far (pure; JSON-round-trippable)."""
        fleet = self._fleet
        return SessionReport(
            objective=self.objective,
            quantile=fleet.scheduler.quantile,
            budget_w=fleet.budget_w,
            decisions={job_id: job.decision
                       for job_id, job in fleet.jobs.items()
                       if job.decision is not None},
            schedule=fleet.repacks[-1] if fleet.repacks else None,
            repacks=len(fleet.repacks),
            chunks_dropped=fleet._dropped,
            retired=dict(self._retired),
            events=list(fleet.events),
            device_health=fleet.device_health(),
            discovery=self._discovery.report_record()
                      if self._discovery is not None else None)

    # -- helpers ---------------------------------------------------------
    def _resolve_device(self, device) -> DeviceInstance:
        if isinstance(device, DeviceInstance):
            return device
        if isinstance(device, str):
            if self.inventory is None:
                raise ValueError(f"device_id {device!r} given but the "
                                 f"session has no inventory")
            return self.inventory.get(device)
        if device is not None:
            raise TypeError(f"device must be a DeviceInstance, a device_id, "
                            f"or None, got {type(device).__name__}")
        if self.inventory is not None and len(self.inventory):
            # round-robin over HEALTHY devices only: failed/degraded chips
            # take no new placements (an all-healthy inventory walks the
            # exact pre-FT order)
            for _ in range(len(self.inventory)):
                dev = self.inventory[self._rr % len(self.inventory)]
                self._rr += 1
                if self.inventory.is_healthy(dev.device_id):
                    return dev
            raise ValueError("no healthy device left in the inventory; "
                             "restore_device one or pass a device explicitly")
        if self._default_device is None:
            # the nominal reference chip: scales exactly 1.0, so decisions
            # are byte-identical to the device-less single-job path
            self._default_device = DeviceInventory.generate(1)[0]
        return self._default_device

    def _unique_job_id(self, base: str, taken=()) -> str:
        """De-duplicate a default job_id; ``taken`` carries ids claimed
        earlier in the same ``submit_many`` batch."""
        job_id, k = base, 1
        while job_id in self._fleet.jobs or job_id in self._retired \
                or job_id in taken:
            k += 1
            job_id = f"{base}#{k}"
        return job_id
