"""Typed, JSON-round-trippable result objects for the session facade.

The facade's outputs are the three decision artifacts a deployment needs to
persist or ship over the wire:

  * ``CapDecision``  — one job's online frequency-cap decision (from
    ``repro.pipeline``), with its full Algorithm 1 ``FreqSelection``;
  * ``JobPlan`` / ``ScheduleResult`` — the per-job power reservation and
    the packed placement (from ``repro.sched``), device_id-tagged on a
    fleet;
  * ``SessionReport`` — the whole session outcome: every live decision,
    the final packing, repack/drop counters, the retired jobs, and the
    fleet's fault-tolerance trail (``FleetEvent``s + device health).

``to_dict``/``from_dict`` (and the ``to_json``/``from_json`` wrappers)
round-trip all of them losslessly: dataclasses are tagged with their type
name, field order follows the dataclass definition (stable across runs),
dict insertion order is preserved by JSON, and numpy scalars are coerced to
the matching Python ``float``/``int`` on the way out — so a decoded object
compares equal to the original.
"""
from __future__ import annotations

import dataclasses
import json
import math
from dataclasses import dataclass, field

import numpy as np

from repro.core.algorithm1 import FreqSelection
from repro.fleet.controller import FleetEvent
from repro.pipeline.online import CapDecision
from repro.sched.power_sched import JobPlan, ScheduleResult

_TYPE_KEY = "__type__"


@dataclass
class SessionReport:
    """Snapshot of a ``MinosSession``'s outcome (JSON-round-trippable)."""
    objective: str
    quantile: str                # provisioning quantile name
    budget_w: float
    decisions: dict[str, CapDecision] = field(default_factory=dict)
    schedule: ScheduleResult | None = None
    repacks: int = 0
    chunks_dropped: int = 0      # telemetry skipped after early decisions
    retired: dict[str, CapDecision | None] = field(default_factory=dict)
    events: list = field(default_factory=list)     # FleetEvents, in order
    device_health: dict[str, str] = field(default_factory=dict)
    # online class-discovery summary (library version, pool depth,
    # promotions, discovered class names); None on discovery-less sessions
    # — old serialized reports (without the key) decode unchanged
    discovery: dict | None = None

    @property
    def early_decisions(self) -> int:
        return sum(d.early for d in self.decisions.values())

    @property
    def migrations(self) -> int:
        """Jobs moved (or elastically shrunk) by failure/degrade handling."""
        return sum(e.kind in ("migrate", "shrink") for e in self.events)

    @property
    def failures(self) -> int:
        return sum(e.kind == "fail" for e in self.events)

    @property
    def n_jobs(self) -> int:
        """Jobs with a recorded outcome: decided live jobs + retired ones
        (live jobs that have not decided yet are not in the report)."""
        return len(self.decisions) + len(self.retired)

    def to_json(self, indent: int | None = None) -> str:
        return to_json(self, indent=indent)

    @classmethod
    def from_json(cls, text: str) -> "SessionReport":
        obj = from_json(text)
        if not isinstance(obj, cls):
            raise TypeError(f"expected a serialized SessionReport, "
                            f"got {type(obj).__name__}")
        return obj


# the closed set of types the codec round-trips; a closed set keeps
# from_dict safe to call on untrusted text (no arbitrary class lookup)
_CODEC_TYPES: dict[str, type] = {
    cls.__name__: cls
    for cls in (FreqSelection, CapDecision, JobPlan, ScheduleResult,
                SessionReport, FleetEvent)
}


def to_dict(obj):
    """Recursively encode a result object into JSON-ready primitives."""
    if type(obj).__name__ in _CODEC_TYPES and dataclasses.is_dataclass(obj):
        out = {_TYPE_KEY: type(obj).__name__}
        for f in dataclasses.fields(obj):
            out[f.name] = to_dict(getattr(obj, f.name))
        return out
    if isinstance(obj, dict):
        if any(not isinstance(k, str) for k in obj):
            raise TypeError(f"only string dict keys serialize, got {obj!r}")
        return {k: to_dict(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [to_dict(v) for v in obj]
    if isinstance(obj, np.floating):
        obj = float(obj)
    elif isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, float) and not math.isfinite(obj):
        # inf (e.g. an unbounded session budget) is not valid RFC JSON;
        # tag it so strict consumers can parse the text and we can decode
        return {"__float__": repr(obj)}
    if isinstance(obj, (bool, int, float, str)) or obj is None:
        return obj
    raise TypeError(f"{type(obj).__name__} is not serializable by "
                    f"repro.api.results (supported result types: "
                    f"{', '.join(sorted(_CODEC_TYPES))})")


def from_dict(data):
    """Inverse of ``to_dict``: rebuild tagged dataclasses recursively."""
    if isinstance(data, dict):
        if set(data) == {"__float__"}:
            return float(data["__float__"])
        tag = data.get(_TYPE_KEY)
        if tag is None:
            return {k: from_dict(v) for k, v in data.items()}
        try:
            cls = _CODEC_TYPES[tag]
        except KeyError:
            raise ValueError(f"unknown serialized type {tag!r}; expected one "
                             f"of {', '.join(sorted(_CODEC_TYPES))}") from None
        kw = {k: from_dict(v) for k, v in data.items() if k != _TYPE_KEY}
        return cls(**kw)
    if isinstance(data, list):
        return [from_dict(v) for v in data]
    return data


def to_json(obj, indent: int | None = None) -> str:
    # allow_nan=False: non-finite floats must have been tagged by to_dict,
    # so the emitted text is strict RFC JSON any consumer can parse
    return json.dumps(to_dict(obj), indent=indent, allow_nan=False)


def from_json(text: str):
    return from_dict(json.loads(text))
