"""``repro.api`` — the one front door to the Minos reproduction.

The paper's pitch is that a single low-cost profiling + classification
mechanism serves many objectives across diverse workloads and heterogeneous
devices.  This package is that pitch as an API: a ``MinosSession`` owns the
reference library, the device inventory, the budget, and the policy plugins,
and every scenario — one job on one chip, a heterogeneous fleet under an
oversubscribed budget, a custom objective — is a few calls on it:

    from repro.api import MinosSession

    session = MinosSession.from_config({
        "library": "results/reference_store",
        "devices": {"tpu-v5e": 6, "tpu-v5p": 2},
        "variability": {},
        "budget_fraction_of_nameplate": 0.75,
    })
    job = session.submit(stream, chips=256)     # -> JobHandle
    decision = job.run()                        # early, confidence-gated cap
    report = session.run()                      # SessionReport (JSON-able)

Everything the facade builds on is re-exported here, so application code
(examples, benchmarks, launchers) needs imports from ``repro.api`` (and
``repro.fleet`` for fleet-specific types) only — enforced for the migrated
entry points by ``tests/test_import_boundary.py``.

Deprecated entry points routing through this stack: the batch
``repro.telemetry.profile_once``/``profile_workload`` (use
``stream_profile_once``/``stream_profile_workload`` or ``session.submit``)
and ``repro.core.reference_store`` (use ``ReferenceLibrary``).
"""
from repro.api.registry import (ACTUATORS, OBJECTIVES, QUANTILES,
                                QuantilePolicy, Registry, register_actuator,
                                register_objective, register_quantile)
from repro.api.results import (SessionReport, from_dict, from_json, to_dict,
                               to_json)
from repro.api.session import JobHandle, MinosSession

# the engine underneath, re-exported so facade users need one import root
from repro.core.algorithm1 import (FreqSelection, ObjectivePolicy,
                                   profiling_savings, resolve_objective,
                                   select_optimal_freq)
from repro.core.classify import (FreqPoint, MinosClassifier, WorkloadProfile,
                                 count_classifier_calls)
from repro.discovery import (DiscoveryController, QuarantinePool,
                             ShadowEvaluator, stream_profiler,
                             truth_selection)
from repro.fleet.controller import FleetCapController, FleetEvent, FleetResult
from repro.fleet.inventory import (DeviceInstance, DeviceInventory,
                                   VariabilityModel)
from repro.fleet.mux import FleetChunk, FleetTelemetryMux
from repro.ft.fleetwatch import FleetStragglerAdapter
from repro.ft.heartbeat import StragglerMonitor
from repro.pipeline.batch import BatchProfileEngine, SlotBuilder
from repro.pipeline.builder import (PartialProfile, ProfileBuilder,
                                    stream_profile_once,
                                    stream_profile_workload)
from repro.pipeline.library import ReferenceLibrary, build_reference_library
from repro.pipeline.online import CapDecision, OnlineCapController
from repro.sched.dvfs import FrequencyActuator, SimActuator
from repro.sched.power_sched import (IncrementalPacker, JobPlan,
                                     PowerAwareScheduler, RepackStats,
                                     ScheduleResult)
from repro.store import (EventJournal, JournalRecord, NoStoreError,
                         SessionStore, SnapshotStore, StoreError,
                         store_report, windowed_report)
from repro.telemetry.kernel_stream import (Kernel, KernelStream, build_stream,
                                           micro_gemm, micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil,
                                           micro_vector_search)
from repro.telemetry.power_model import TPUPowerModel
from repro.telemetry.simulator import (SimTrace, TelemetryChunk, TraceMeta,
                                       simulate, stream_telemetry)
from repro.telemetry.workloads import (fleet_job_mix, holdout_streams,
                                       novel_streams, reference_streams)

__all__ = [
    # facade
    "MinosSession", "JobHandle", "SessionReport",
    # registries / plugin policies
    "Registry", "OBJECTIVES", "ACTUATORS", "QUANTILES",
    "register_objective", "register_actuator", "register_quantile",
    "ObjectivePolicy", "QuantilePolicy", "resolve_objective",
    # result objects + codec
    "CapDecision", "JobPlan", "ScheduleResult", "FreqSelection",
    "IncrementalPacker", "RepackStats",
    "to_dict", "from_dict", "to_json", "from_json",
    # streaming pipeline
    "ProfileBuilder", "PartialProfile", "ReferenceLibrary",
    "build_reference_library", "OnlineCapController",
    "stream_profile_once", "stream_profile_workload",
    "BatchProfileEngine", "SlotBuilder",
    # classification core
    "MinosClassifier", "WorkloadProfile", "FreqPoint",
    "select_optimal_freq", "profiling_savings", "count_classifier_calls",
    # fleet
    "DeviceInstance", "DeviceInventory", "VariabilityModel",
    "FleetCapController", "FleetResult", "FleetChunk", "FleetTelemetryMux",
    # fault tolerance
    "FleetEvent", "FleetStragglerAdapter", "StragglerMonitor",
    # durable sessions (repro.store)
    "SessionStore", "EventJournal", "JournalRecord", "SnapshotStore",
    "NoStoreError", "StoreError", "store_report", "windowed_report",
    # online class discovery (repro.discovery)
    "DiscoveryController", "QuarantinePool", "ShadowEvaluator",
    "stream_profiler", "truth_selection",
    # actuation / scheduling
    "FrequencyActuator", "SimActuator", "PowerAwareScheduler",
    # telemetry + workload zoo
    "TPUPowerModel", "simulate", "stream_telemetry", "SimTrace",
    "TelemetryChunk", "TraceMeta", "Kernel", "KernelStream", "build_stream",
    "micro_gemm", "micro_idle_burst", "micro_spmv_compute",
    "micro_spmv_memory", "micro_stencil", "micro_vector_search",
    "reference_streams", "holdout_streams", "novel_streams", "fleet_job_mix",
]
