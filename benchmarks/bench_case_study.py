"""Paper §7.1 / Fig. 8 / Table 2: never-before-seen workloads.

Held-out targets (never in the reference set):
  * vector-search            — the FAISS analogue
  * granite-moe (train+decode) — the Qwen1.5-MoE analogue (unseen MoE arch)

Minos sees ONE uncapped profile per target; predictions are validated against
the ground-truth frequency sweep the simulator produces for evaluation only.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (RESULTS, degradation, emit, nearest_freq,
                               reference_library)
from repro.analysis.hardware import FREQ_SWEEP
from repro.core import select_optimal_freq
from repro.core.algorithm1 import PERF_BOUND, POWER_BOUND, profiling_savings
from repro.telemetry import build_holdout_profiles


def run() -> dict:
    t0 = time.time()
    lib = reference_library()
    clf = lib.classifier()
    observed, truth = build_holdout_profiles(with_truth=True)
    truth_by_name = {t.name: t for t in truth}

    rows = []
    for obs in observed:
        tru = truth_by_name[obs.name]
        sel = select_optimal_freq(obs, clf)
        nn_pwr = lib.get(sel.power_neighbor)
        nn_perf = lib.get(sel.util_neighbor)
        # PowerCentric: does the chosen cap keep the target's true p90 under
        # 1.3x TDP?  error := observed p90 - bound (positive = violated)
        obs_p90 = tru.scaling[nearest_freq(tru, sel.f_pwr)].p90
        pwr_err = max(obs_p90 - POWER_BOUND, 0.0)
        # PerfCentric: observed degradation at the chosen cap vs the 5% bound
        obs_degr = degradation(tru, sel.f_perf)
        perf_err = max(obs_degr - PERF_BOUND, 0.0)
        savings = profiling_savings(tru, list(FREQ_SWEEP))
        rows.append({
            "target": obs.name,
            "power_neighbor": sel.power_neighbor,
            "cos_distance": round(sel.power_distance, 4),
            "perf_neighbor": sel.util_neighbor,
            "eucl_distance": round(sel.util_distance, 4),
            "bin_size": sel.bin_size,
            "f_pwr": sel.f_pwr, "f_perf": sel.f_perf,
            "observed_p90_at_cap": round(obs_p90, 4),
            "power_bound_violation": round(pwr_err, 4),
            "observed_degr_at_cap": round(obs_degr, 4),
            "perf_bound_violation": round(perf_err, 4),
            "profiling_savings": round(savings, 4),
        })
    with open(os.path.join(RESULTS, "case_study.json"), "w") as f:
        json.dump(rows, f, indent=1)
    mean_sav = np.mean([r["profiling_savings"] for r in rows])
    worst_pwr = max(r["power_bound_violation"] for r in rows)
    worst_perf = max(r["perf_bound_violation"] for r in rows)
    emit("case_study_fig8_table2", (time.time() - t0) * 1e6,
         f"savings={mean_sav:.2f};max_pwr_viol={worst_pwr:.3f};"
         f"max_perf_viol={worst_perf:.3f}")
    return {"rows": rows}


if __name__ == "__main__":
    for r in run()["rows"]:
        print(r)
