"""Paper Fig. 7: performance scaling with frequency caps per utilization
class (C/M/H)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.analysis.hardware import FREQ_SWEEP


def run() -> dict:
    t0 = time.time()
    refs = reference_library().profiles
    rows = {}
    for r in refs:
        base = r.scaling[max(r.scaling)].exec_time
        rows[r.name] = {
            str(f): round(r.scaling[f].exec_time / base - 1.0, 4)
            for f in sorted(r.scaling)
        }
    with open(os.path.join(RESULTS, "freq_scaling.json"), "w") as f:
        json.dump(rows, f, indent=1)
    # summarize: worst-cap degradation for a compute vs memory workload
    comp = rows["sgemm-25k"][str(min(FREQ_SWEEP))]
    mem = rows["pagerank-pannotia"][str(min(FREQ_SWEEP))]
    emit("perf_scaling_fig7", (time.time() - t0) * 1e6,
         f"degr@0.6[sgemm]={comp:.2f};degr@0.6[pagerank-mem]={mem:.2f}")
    return rows


if __name__ == "__main__":
    o = run()
    for name in ("sgemm-25k", "pagerank-pannotia", "command-r-35b:train_4k",
                 "command-r-35b:decode_32k", "jamba-1.5-large-398b:train_4k"):
        print(f"{name:34s}", {k: f"{v:+.2f}" for k, v in o[name].items()})
