"""Fleet-scale online capping: throughput, budget safety, and reclaimed
provisioning headroom on a heterogeneous variability-aware pod.

A seeded ``DeviceInventory`` (three chip generations, per-device silicon
variability) runs a seeded job mix through one ``repro.api.MinosSession``:
every job is a ``submit`` of its single low-cost profiling run, and
``session.run()`` multiplexes the telemetry, caps early per job, and
re-packs the shared power budget on every decision.  The resulting
placement is then validated against ground truth: each placed job is
re-simulated *at its cap on its device* and the time-aligned aggregate
fleet power is checked against the budget.

Emits one ``emit()`` row and writes ``results/fleet.json``:
  * ``jobs_per_s``          — classification throughput of the fleet feed;
  * ``budget_violations``   — samples where the sustained (50-sample rolling
    mean) aggregate exceeds the budget — expected **0**;
  * ``headroom_reclaimed_w`` — nameplate TDP provisioning minus the packed
    p99 plan: the watts Minos hands back to the facility.

``--smoke`` runs a micro-zoo configuration for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.api import (DeviceInventory, MinosSession, ReferenceLibrary,
                       TPUPowerModel, VariabilityModel, fleet_job_mix,
                       micro_gemm, micro_idle_burst, micro_spmv_compute,
                       micro_spmv_memory, micro_stencil, simulate,
                       stream_profile_workload)

SUSTAIN_WINDOW = 50              # samples (~50 ms at 1 kHz) for the rolling mean
BUDGET_FRACTION = 0.75           # of nameplate: the oversubscription target


def _sustained(agg: np.ndarray, window: int = SUSTAIN_WINDOW) -> np.ndarray:
    if len(agg) < window:
        return np.array([agg.mean()]) if len(agg) else np.zeros(1)
    kernel = np.ones(window) / window
    return np.convolve(agg, kernel, mode="valid")


def run(smoke: bool = False) -> dict:
    if smoke:
        counts = {"tpu-v5e": 2, "tpu-v5p": 1}
        streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
                   micro_idle_burst(), micro_stencil()]
        model = TPUPowerModel()
        lib = ReferenceLibrary(
            (stream_profile_workload(s, model, (0.6, 0.8, 1.0),
                                     model.spec.tdp_w, seed=i,
                                     target_duration=1.0)
             for i, s in enumerate(streams)),
            built_on=model.spec.name)
        jobs = [(s, 4 * (i % 3 + 1)) for i, s in enumerate(streams)]
        target_duration = 1.0
    else:
        counts = {"tpu-v5e": 6, "tpu-v5p": 3, "tpu-v6e": 3}
        lib = reference_library()
        jobs = fleet_job_mix(16, seed=11)
        target_duration = 2.0

    inventory = DeviceInventory.generate(counts, VariabilityModel(), seed=7)
    # round-robin jobs over devices; budget oversubscribes total nameplate
    assigned = [(s, chips, inventory[i % len(inventory)])
                for i, (s, chips) in enumerate(jobs)]
    nameplate = sum(chips * dev.nameplate_w for _, chips, dev in assigned)
    budget = BUDGET_FRACTION * nameplate

    session = MinosSession(lib, inventory=inventory, budget_w=budget,
                           objective="powercentric", quantile="p99",
                           min_confidence=0.2)
    for i, (stream, chips, dev) in enumerate(assigned):
        session.submit(stream, device=dev, chips=chips,
                       job_id=f"j{i:02d}:{stream.name}", seed=500 + i,
                       target_duration=target_duration)

    t0 = time.perf_counter()
    report = session.run()
    elapsed = time.perf_counter() - t0
    jobs_per_s = len(assigned) / elapsed

    # ground truth: re-simulate every *placed* job at its cap on its device,
    # sum the time-aligned per-chip traces, and check sustained power.
    # Plans carry the exact job_id, so matching is unambiguous even when
    # the with-replacement mix repeats a workload on a device.
    placed = {p.job_id: p for p in report.schedule.placed}
    traces = []
    for i, (stream, chips, dev) in enumerate(assigned):
        plan = placed.pop(f"j{i:02d}:{stream.name}", None)
        if plan is None:
            continue                       # deferred: draws no power
        tr = simulate(stream, plan.cap, dev.power_model(), seed=500 + i,
                      target_duration=target_duration)
        traces.append(plan.chips * tr.power_filtered)
    assert not placed, f"unmatched placed plans: {sorted(placed)}"
    if traces:
        # align to the LONGEST window: the workloads are periodic, so a
        # shorter trace is tiled (the job keeps running its pattern) — no
        # tail samples escape the budget check
        n = max(len(t) for t in traces)
        aggregate = np.sum([np.resize(t, n) for t in traces], axis=0)
    else:
        aggregate = np.zeros(1)            # everything deferred: no draw
    sustained = _sustained(aggregate)
    violations = int(np.sum(sustained > budget))

    out = {
        "config": {
            "smoke": smoke,
            "devices": {m: len(inventory.by_model(m))
                        for m in inventory.models},
            "n_jobs": len(assigned),
            "budget_w": round(budget, 1),
            "budget_fraction_of_nameplate": BUDGET_FRACTION,
            "provision_quantile": report.quantile,
        },
        "jobs_per_s": round(jobs_per_s, 2),
        "early_decisions": report.early_decisions,
        "repacks": report.repacks,
        "chunks_dropped": report.chunks_dropped,
        "placed": len(report.schedule.placed),
        "deferred": len(report.schedule.deferred),
        "planned_power_w": round(report.schedule.planned_power_w, 1),
        "nameplate_power_w": round(report.schedule.nameplate_power_w, 1),
        "headroom_reclaimed_w": round(report.schedule.headroom_reclaimed_w, 1),
        "budget_violations": violations,
        "peak_sustained_w": round(float(sustained.max()), 1),
        "peak_instant_w": round(float(aggregate.max()), 1),
        "decisions": {
            job_id: {"cap": d.cap, "early": d.early,
                     "fraction": round(d.fraction, 3),
                     "device": d.device_id,
                     "neighbor": d.selection.power_neighbor}
            for job_id, d in sorted(report.decisions.items())
        },
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fleet.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("fleet_online_cap", elapsed * 1e6,
         f"jobs/s={jobs_per_s:.1f};violations={violations};"
         f"headroom_kW={out['headroom_reclaimed_w'] / 1e3:.1f}")
    assert violations == 0, (
        f"fleet exceeded its power budget in {violations} sustained windows "
        f"(peak {sustained.max():.0f} W vs budget {budget:.0f} W)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="micro-zoo configuration for CI")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
