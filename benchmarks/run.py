"""Benchmark harness — one entry per paper table/figure + the roofline table.

Prints ``name,us_per_call,derived`` CSV lines (see each bench module for the
JSON artifacts written under results/).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (bench_baseline_cmp, bench_binsize, bench_case_study,
                            bench_cdf, bench_chaos, bench_classification,
                            bench_discovery, bench_fleet, bench_fleet_scale,
                            bench_freq_scaling, bench_holdout, bench_kernels,
                            bench_online_cap, bench_profiling_throughput,
                            bench_recovery, bench_roofline, bench_savings)

    print("name,us_per_call,derived")
    failures = []
    for mod in (bench_classification, bench_cdf, bench_freq_scaling,
                bench_case_study, bench_holdout, bench_baseline_cmp,
                bench_binsize, bench_savings, bench_kernels, bench_roofline,
                bench_profiling_throughput, bench_online_cap, bench_fleet,
                bench_fleet_scale, bench_chaos, bench_recovery,
                bench_discovery):
        try:
            mod.run()
        except Exception:
            failures.append(mod.__name__)
            traceback.print_exc()
    if failures:
        print(f"# FAILED: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
