"""Online capping convergence: how much of a profiling trace does the
pipeline need before its cap decision matches the full-profile one?

For every zoo workload, the single uncapped profiling run is submitted to a
``repro.api.MinosSession`` (hold-one-out against the shipped reference
library) and fed chunk by chunk with ``profile_to_completion`` on; at each
trace-fraction checkpoint the partial profile (``JobHandle.snapshot``) is
pushed through Algorithm 1 and the chosen cap is compared with the decision
from the completed profile.  The session's confidence gate rides along on
the same feed, recording where it would have stopped profiling and whether
that early call was right.

Emits one ``emit()`` row and writes ``results/online_cap.json``:
  * ``agreement_curve`` — fraction-of-trace -> share of workloads whose
    online cap equals the full-profile cap (both objectives);
  * ``agreement_at_half`` — the headline: >= 0.9 expected at 50% of trace;
  * per-workload convergence fractions and controller early-stop stats.

``--smoke`` runs a micro-zoo configuration for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.api import (MinosSession, ReferenceLibrary, TPUPowerModel,
                       micro_gemm, micro_idle_burst, micro_spmv_compute,
                       micro_spmv_memory, micro_stencil, reference_streams,
                       select_optimal_freq, stream_profile_workload,
                       stream_telemetry)

FRACTIONS = (0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0)


def _caps(sel) -> dict:
    return {"powercentric": sel.f_pwr, "perfcentric": sel.f_perf}


def run(smoke: bool = False) -> dict:
    t0 = time.time()
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    if smoke:
        streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
                   micro_idle_burst(), micro_stencil()]
        lib = ReferenceLibrary(
            stream_profile_workload(s, model, (0.6, 0.8, 1.0), tdp, seed=i,
                                    target_duration=1.0)
            for i, s in enumerate(streams))
        target_duration = 2.0
    else:
        streams = reference_streams()
        lib = reference_library()
        target_duration = 4.0

    # one session serves every target: the confidence gate (powercentric)
    # rides along on each feed, while the checkpoint classification below
    # hits the same shared warm classifier
    session = MinosSession(lib, objective="powercentric", actuator="none",
                           min_confidence=0.2)
    clf = session.classifier

    rows = []
    agree = {obj: {f: 0 for f in FRACTIONS}
             for obj in ("powercentric", "perfcentric")}
    for i, stream in enumerate(streams):
        meta, chunks = stream_telemetry(stream, 1.0, model, seed=1000 + i,
                                        target_duration=target_duration)
        job = session.submit(meta, profile_to_completion=True)
        partial = {}
        next_f = 0
        for chunk in chunks:
            job.feed(chunk)
            while next_f < len(FRACTIONS) and \
                    job.fraction >= FRACTIONS[next_f] - 1e-12:
                sel = select_optimal_freq(job.snapshot(), clf)
                partial[FRACTIONS[next_f]] = _caps(sel)
                next_f += 1
        gate_decision = job.decision(finalize=False)
        final_sel = select_optimal_freq(job.profile(), clf)
        final = _caps(final_sel)
        for f in FRACTIONS[next_f:]:
            partial[f] = final
        conv = {}
        for obj in agree:
            # convergence: earliest checkpoint from which the online cap
            # matches the full-profile cap at every later checkpoint too
            conv_f = 1.0
            for f in reversed(FRACTIONS):
                if partial[f][obj] != final[obj]:
                    break
                conv_f = f
            conv[obj] = conv_f
            for f in FRACTIONS:
                agree[obj][f] += partial[f][obj] == final[obj]
        rows.append({
            "target": meta.name,
            "final_cap": final,
            "converged_at": conv,
            "gate_fraction": None if gate_decision is None
            else round(gate_decision.fraction, 3),
            "gate_confidence": None if gate_decision is None
            else round(gate_decision.confidence, 3),
            "gate_cap_matches": None if gate_decision is None
            else gate_decision.cap == final["powercentric"],
        })

    n = len(streams)
    curve = {obj: {str(f): round(agree[obj][f] / n, 4) for f in FRACTIONS}
             for obj in agree}
    at_half = {obj: agree[obj][0.5] / n for obj in agree}
    gated = [r for r in rows if r["gate_fraction"] is not None]
    gate_stats = {
        "decided_early": len(gated),
        "n_targets": n,
        "mean_fraction": round(float(np.mean(
            [r["gate_fraction"] for r in gated])), 3) if gated else None,
        "cap_match_rate": round(float(np.mean(
            [r["gate_cap_matches"] for r in gated])), 3) if gated else None,
    }
    out = {
        "config": {"smoke": smoke, "n_targets": n,
                   "target_duration_s": target_duration},
        "agreement_curve": curve,
        "agreement_at_half": {k: round(v, 4) for k, v in at_half.items()},
        "meets_90pct_at_half": all(v >= 0.9 for v in at_half.values()),
        "controller_gate": gate_stats,
        "per_workload": rows,
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "online_cap.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("online_cap_convergence", (time.time() - t0) * 1e6,
         f"agree@50%={at_half['powercentric']:.2f}/"
         f"{at_half['perfcentric']:.2f};early={gate_stats['decided_early']}"
         f"/{n}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="micro-zoo configuration for CI")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
