"""Paper §7.4 / Fig. 12: bin-size sensitivity of p90 prediction error,
normalized to bin size 0.1."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library, unique_library

BIN_SIZES = (0.05, 0.1, 0.15, 0.2, 0.25, 0.5, 0.75)


def run() -> dict:
    t0 = time.time()
    uniq_lib = unique_library(reference_library())
    uniq = uniq_lib.profiles
    clf = uniq_lib.classifier()
    errs = {}
    p90 = {r.name: r.p_quantile(90) for r in uniq}
    for c in BIN_SIZES:
        neighbors = clf.power_neighbors(uniq, bin_size=c)
        errs[c] = float(np.mean([abs(p90[t.name] - p90[nn.name])
                                 for t, (nn, _) in zip(uniq, neighbors)]))
    base = errs[0.1] or 1e-9
    norm = {str(c): round(errs[c] / base, 3) for c in BIN_SIZES}
    out = {"raw": {str(c): round(v, 4) for c, v in errs.items()},
           "normalized_to_0.1": norm}
    with open(os.path.join(RESULTS, "binsize.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("binsize_fig12", (time.time() - t0) * 1e6,
         ";".join(f"c{c}={norm[str(c)]}" for c in BIN_SIZES))
    return out


if __name__ == "__main__":
    print(run())
