"""Paper §7.3 / Fig. 9b + Fig. 10: Minos vs the Guerreiro et al. mean-power
classifier under the identical hold-one-out protocol."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (RESULTS, emit, holdout_power_error,
                               reference_library, unique_library)
from repro.core.baselines import mean_power_neighbor, util_only_neighbor


def run() -> dict:
    t0 = time.time()
    uniq_lib = unique_library(reference_library())
    uniq = uniq_lib.profiles
    clf = uniq_lib.classifier()
    rows = []
    for target in uniq:
        nn_minos, _ = clf.power_neighbor(target)
        nn_mean, _ = mean_power_neighbor(target, uniq)
        nn_util, _ = util_only_neighbor(target, uniq)
        rec = {"target": target.name}
        for tag, nn in (("minos", nn_minos), ("guerreiro", nn_mean),
                        ("util_only", nn_util)):
            for q in ("p90", "p95", "p99"):
                err, _, _ = holdout_power_error(target, nn, q)
                rec[f"{tag}_{q}"] = round(err, 4)
            rec[f"{tag}_nn"] = nn.name
        rows.append(rec)
    means = {}
    for tag in ("minos", "guerreiro", "util_only"):
        for q in ("p90", "p95", "p99"):
            means[f"{tag}_{q}"] = round(float(np.mean(
                [r[f"{tag}_{q}"] for r in rows])), 4)
    out = {"rows": rows, "means": means}
    with open(os.path.join(RESULTS, "baseline_cmp.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("baseline_cmp_fig9b_fig10", (time.time() - t0) * 1e6,
         f"minos_p90={means['minos_p90']:.3f};"
         f"guerreiro_p90={means['guerreiro_p90']:.3f};"
         f"util_only_p90={means['util_only_p90']:.3f}")
    return out


if __name__ == "__main__":
    o = run()
    print(o["means"])
