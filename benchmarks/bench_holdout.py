"""Paper §7.2 / Figs. 9-11: hold-one-out generalization across unique
workloads — p90/p95/p99 power and performance prediction errors, plus the
error-vs-neighbor-distance histograms."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import (RESULTS, emit, holdout_neighbors,
                               holdout_perf_error, holdout_power_error,
                               reference_library, unique_library)


def run() -> dict:
    t0 = time.time()
    uniq_lib = unique_library(reference_library())
    uniq = uniq_lib.profiles
    clf = uniq_lib.classifier()
    pwr_nn, util_nn = holdout_neighbors(clf, uniq)
    rows = []
    for target, (nn_pwr, d_pwr), (nn_perf, d_perf) in zip(uniq, pwr_nn, util_nn):
        rec = {"target": target.name, "power_neighbor": nn_pwr.name,
               "cos_distance": round(d_pwr, 4),
               "perf_neighbor": nn_perf.name,
               "eucl_distance": round(d_perf, 4)}
        for q in ("p90", "p95", "p99"):
            err, f, obs = holdout_power_error(target, nn_pwr, q)
            rec[f"{q}_err"] = round(err, 4)
            rec[f"{q}_cap"] = f
        perr, pf, pobs = holdout_perf_error(target, nn_perf)
        rec["perf_err"] = round(perr, 4)
        rec["perf_cap"] = pf
        rows.append(rec)

    mean = {q: float(np.mean([r[f"{q}_err"] for r in rows]))
            for q in ("p90", "p95", "p99")}
    mean["perf"] = float(np.mean([r["perf_err"] for r in rows]))
    perfect = sum(1 for r in rows if r["perf_err"] < 0.005)

    # Fig 9c / 11c: error binned by distance
    def binify(rows, dist_key, err_key, edges):
        out = []
        for lo, hi in zip(edges[:-1], edges[1:]):
            sel = [r[err_key] for r in rows if lo <= r[dist_key] < hi]
            out.append({"bin": f"[{lo},{hi})", "n": len(sel),
                        "mean_err": round(float(np.mean(sel)), 4) if sel else None})
        return out

    result = {
        "rows": rows,
        "mean_errors": {k: round(v, 4) for k, v in mean.items()},
        "perfect_perf_predictions": f"{perfect}/{len(rows)}",
        "err_by_cos_distance": binify(rows, "cos_distance", "p90_err",
                                      [0, 0.02, 0.05, 0.1, 0.25, 1.01]),
        "err_by_eucl_distance": binify(rows, "eucl_distance", "perf_err",
                                       [0, 0.05, 0.1, 0.2, 0.5, 10.0]),
    }
    with open(os.path.join(RESULTS, "holdout.json"), "w") as f:
        json.dump(result, f, indent=1)
    emit("holdout_fig9_10_11", (time.time() - t0) * 1e6,
         f"p90={mean['p90']:.3f};p95={mean['p95']:.3f};p99={mean['p99']:.3f};"
         f"perf={mean['perf']:.3f};perfect={perfect}/{len(rows)}")
    return result


if __name__ == "__main__":
    o = run()
    print("mean errors:", o["mean_errors"], o["perfect_perf_predictions"])
    for r in o["rows"]:
        print(f"  {r['target']:36s} pwrNN={r['power_neighbor']:28s} "
              f"d={r['cos_distance']:.3f} p90err={r['p90_err']:.3f} "
              f"perfNN={r['perf_neighbor']:28s} perferr={r['perf_err']:.3f}")
