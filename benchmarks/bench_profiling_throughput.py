"""End-to-end profiling-engine throughput: seed loops vs vectorized engine.

Minos's pitch is *low-cost* profiling, so the repro's own hot path has to be
cheap too.  This benchmark times the two stages the paper's workflow runs
constantly, before and after PR 1's vectorized event-stream engine:

  1. reference-library build — ``simulate`` (event integration + EMA) over a
     set of kernel streams at several frequencies;
  2. hold-one-out classification — per-target ``choose_bin_size`` (6 bin
     sizes) + power/util nearest-neighbor over the library.

"before" is ``repro.legacy`` (the frozen seed implementations: dense
O(E x S) integration, per-sample Python EMA, per-call spike-vector
recomputation); "after" is the shipped engine (prefix-sum + ``np.interp``
integration, log-doubling EMA, cached spike matrices + batched
distance-matrix neighbors).  Golden tests in
``tests/test_profiling_engine.py`` pin both to identical outputs, so this
measures the same computation.

Emits two ``emit()`` rows (build, classify) and writes
``results/profiling_throughput.json`` with the speedups.  ``--smoke`` runs a
seconds-scale configuration for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from repro import legacy
from repro.core import MinosClassifier, WorkloadProfile
from repro.core.algorithm1 import DEFAULT_BIN_CANDIDATES
from repro.configs import ARCHS, SHAPES
from repro.telemetry import TPUPowerModel, simulate
from repro.telemetry.kernel_stream import (build_stream, micro_gemm,
                                           micro_idle_burst,
                                           micro_spmv_compute,
                                           micro_spmv_memory, micro_stencil)
from repro.core import spikes as spk
from repro.core.classify import FreqPoint


def _streams(smoke: bool):
    out = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
           micro_idle_burst(), micro_stencil()]
    if not smoke:
        # dense-kernel-stream LLM cells: the event counts the fleet actually
        # produces (hundreds of kernels per training step)
        out += [build_stream(ARCHS[a], SHAPES["train_4k"], 256)
                for a in ("glm4-9b", "command-r-35b")]
    return out


def _sweep_profile(stream, model, freqs, tdp, simulate_fn, seed,
                   target_duration):
    """The batch reference sweep (the pre-PR-4 ``profile_workload`` body) on
    top of a pluggable simulate, so before/after share every non-measured
    line.  (The public ``profile_workload`` now routes through the streaming
    builder and no longer calls ``simulate``, so the seed-vs-vectorized
    integration comparison keeps its own sweep loop.)"""
    scaling = {}
    top = max(freqs)
    top_trace = None
    for i, f in enumerate(sorted(freqs)):
        tr = simulate_fn(stream, f, model, seed=seed * 1009 + i,
                         target_duration=target_duration)
        scaling[f] = FreqPoint(
            freq=f, p90=spk.p_quantile(tr.power_filtered, tdp, 90),
            p95=spk.p_quantile(tr.power_filtered, tdp, 95),
            p99=spk.p_quantile(tr.power_filtered, tdp, 99),
            mean_power=spk.mean_power_rel(tr.power_filtered, tdp),
            exec_time=tr.exec_time,
            spike_vec=spk.spike_vector(tr.power_filtered, tdp),
        )
        if f == top:
            top_trace = tr
    return WorkloadProfile(
        name=stream.name, tdp=tdp, power_trace=top_trace.power_filtered,
        sm_util=top_trace.app_sm_util, dram_util=top_trace.app_dram_util,
        exec_time=top_trace.exec_time, scaling=scaling, domain=stream.domain)


def _build_library(simulate_fn, streams, freqs, target_duration, seed=0):
    """Reference-library build: the sweep loop over a pluggable simulate."""
    model = TPUPowerModel()
    tdp = model.spec.tdp_w
    return [_sweep_profile(stream, model, freqs, tdp, simulate_fn,
                           seed + i, target_duration)
            for i, stream in enumerate(streams)]


def _library_scale(refs: list[WorkloadProfile],
                   copies: int) -> list[WorkloadProfile]:
    """Scale the classify stage to shipped-library size (28 profiles) by
    cloning the built profiles under distinct names; traces are shared, so
    this multiplies only the classification work being measured."""
    import dataclasses
    return [dataclasses.replace(r, name=f"{r.name}#{k}")
            for k in range(copies) for r in refs]


def _classify_vectorized(refs: list[WorkloadProfile]) -> None:
    """The shipped hold-one-out protocol: per-candidate batched neighbor
    matrices over ALL targets at once (cached spike matrices underneath),
    then the final per-target neighbor at its best bin size."""
    clf = MinosClassifier(refs)
    p90 = {r.name: r.p_quantile(90) for r in refs}
    errs = np.empty((len(DEFAULT_BIN_CANDIDATES), len(refs)))
    for ci, c in enumerate(DEFAULT_BIN_CANDIDATES):
        neighbors = clf.power_neighbors(refs, bin_size=c)
        errs[ci] = [abs(p90[t.name] - p90[nn.name])
                    for t, (nn, _) in zip(refs, neighbors)]
    best_c = np.argmin(errs, axis=0)
    for ci in set(best_c.tolist()):
        sel = [r for r, b in zip(refs, best_c) if b == ci]
        clf.power_neighbors(sel, bin_size=DEFAULT_BIN_CANDIDATES[ci])
    clf.util_neighbors(refs)


def _classify_seed(refs: list[WorkloadProfile]) -> None:
    """The same protocol as the seed code could only express it: per-target
    bin-size sweep, each query re-histogramming every reference."""
    for target in refs:
        c = legacy.choose_bin_size_loop(target, refs, DEFAULT_BIN_CANDIDATES)
        legacy.power_neighbor_loop(refs, target, bin_size=c)
        legacy.util_neighbor_loop(refs, target)


def run(smoke: bool = True) -> dict:
    # default smoke=True: run.py's aggregate suite calls run() bare and must
    # not pay the ~12 s frozen-seed rebuild; the standalone CLI defaults to
    # the full configuration (the ROADMAP numbers) unless --smoke is given
    freqs = (0.6, 0.8, 1.0) if smoke else (0.6, 0.7, 0.8, 0.9, 1.0)
    dur = 0.5 if smoke else 2.0
    reps = 1 if smoke else 3
    streams = _streams(smoke)

    t0 = time.time()
    refs = _build_library(simulate, streams, freqs, dur)
    t_build_new = time.time() - t0

    t0 = time.time()
    legacy_refs = _build_library(legacy.simulate_dense, streams, freqs, dur)
    t_build_old = time.time() - t0

    assert [r.name for r in refs] == [r.name for r in legacy_refs]

    copies = 1 if smoke else 4            # 7 built profiles x 4 = 28 = shipped
    cls_refs = _library_scale(refs, copies)
    cls_legacy = _library_scale(legacy_refs, copies)

    t0 = time.time()
    for _ in range(reps):
        _classify_vectorized(cls_refs)
    t_cls_new = (time.time() - t0) / reps

    t0 = time.time()
    for _ in range(reps):
        _classify_seed(cls_legacy)
    t_cls_old = (time.time() - t0) / reps

    out = {
        "config": {"smoke": smoke, "n_streams": len(streams),
                   "n_classify_refs": len(cls_refs),
                   "freqs": list(freqs), "target_duration_s": dur},
        "library_build_s": {"seed": round(t_build_old, 4),
                           "vectorized": round(t_build_new, 4),
                           "speedup": round(t_build_old / t_build_new, 2)},
        "classification_s": {"seed": round(t_cls_old, 4),
                             "vectorized": round(t_cls_new, 4),
                             "speedup": round(t_cls_old / t_cls_new, 2)},
        "end_to_end_speedup": round(
            (t_build_old + t_cls_old) / (t_build_new + t_cls_new), 2),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "profiling_throughput.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("profiling_throughput_build", t_build_new * 1e6,
         f"seed={t_build_old:.2f}s;vec={t_build_new:.2f}s;"
         f"x{out['library_build_s']['speedup']}")
    emit("profiling_throughput_classify", t_cls_new * 1e6,
         f"seed={t_cls_old:.3f}s;vec={t_cls_new:.3f}s;"
         f"x{out['classification_s']['speedup']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny configuration for CI (seconds, not minutes)")
    args = ap.parse_args()
    out = run(smoke=args.smoke)           # CLI default: full configuration
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
