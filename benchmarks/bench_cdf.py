"""Paper Fig. 5 (per-class cumulative power distributions) and Fig. 6
(CDF shifts under frequency capping)."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from repro.analysis.hardware import V5E
from repro.core import spikes
from repro.telemetry import TPUPowerModel, simulate
from repro.telemetry.workloads import reference_streams

CAPS = (1.0, 0.8, 0.6)
REPRESENTATIVES = ["sgemm-25k", "pagerank-pannotia", "lsms-like",
                   "command-r-35b:train_4k", "command-r-35b:decode_32k",
                   "deepseek-v2-236b:train_4k"]


def run() -> dict:
    t0 = time.time()
    model = TPUPowerModel()
    tdp = V5E.tdp_w
    grid = np.linspace(0.0, 2.0, 101)
    streams = {s.name: s for s in reference_streams()}
    out = {"grid": grid.tolist(), "cdfs": {}}
    shift = {}
    for name in REPRESENTATIVES:
        out["cdfs"][name] = {}
        p90s = {}
        for f in CAPS:
            tr = simulate(streams[name], f, model, seed=11,
                          target_duration=2.0)
            _, cdf = spikes.spike_cdf(tr.power_filtered, tdp, grid)
            out["cdfs"][name][str(f)] = np.round(cdf, 4).tolist()
            p90s[f] = spikes.p_quantile(tr.power_filtered, tdp, 90)
        shift[name] = p90s[1.0] - p90s[0.6]
    with open(os.path.join(RESULTS, "cdfs.json"), "w") as f:
        json.dump(out, f)
    emit("cdf_fig5_fig6", (time.time() - t0) * 1e6,
         "p90shift[sgemm]=%.2f;p90shift[pagerank]=%.2f" % (
             shift["sgemm-25k"], shift["pagerank-pannotia"]))
    return {"shift": shift, **out}


if __name__ == "__main__":
    o = run()
    print("p90 shift (uncapped - 0.6cap), should be large for compute-bound:")
    for k, v in o["shift"].items():
        print(f"  {k:32s} {v:+.3f} xTDP")
