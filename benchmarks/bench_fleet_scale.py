"""Fleet-scale stress: 10k+ concurrent jobs through the batched columnar
profiling engine — one stacked pass over the whole fleet's telemetry.

The PR 3 fleet loop stepped jobs one at a time through per-job
``ProfileBuilder``s and topped out around 300–370 jobs/s; production GPU
fleets run thousands of concurrent jobs (arXiv:2502.18680).  This bench
admits a serving-weighted 10k-job mix onto a zero-variability three-
generation inventory and drains the multiplexed feed through
``FleetCapController(engine="batched", repack="tick")``: every mux tick
advances all live jobs in one ``BatchProfileEngine.ingest_batch`` columnar
pass, and all of a tick's decisions share one re-pack.

Telemetry is pre-generated once per distinct (workload, chip model) pair
and shared across jobs — chunks are immutable, so 10k builders can read the
same arrays; generation cost is excluded from the timed region (the bench
measures the *profiling engine*, not the simulator).

Emits one ``emit()`` row and writes ``results/fleet_scale.json``:
  * ``jobs_per_s``          — admitted jobs / wall-clock of admit+run, best
    of N identical attempts (the drive is deterministic: every attempt
    lands the same decisions, so the fastest attempt is the engine and the
    rest is co-tenant scheduler noise);
  * ``budget_violations``   — sustained (50-sample rolling mean) aggregate
    samples above the budget, from per-group ground-truth re-simulation —
    expected **0**;
  * ``clf_calls_on_repack`` — classifier invocations triggered by a
    post-run ``set_budget`` re-pack — expected **0** (cached plans only).

ISSUE 8 splits the control-plane cost out of the aggregate number:
  * ``admit_jobs_per_s``    — bulk-admission rate through ONE
    ``FleetCapController.admit_many`` call (validate whole batch, one
    coalesced journal flush), with its own floor;
  * ``repack``              — a replay of the drained plan population
    through the maintained ``IncrementalPacker`` vs a from-scratch
    ``pack()`` per control-plane event (the pre-ISSUE-8 cost model):
    total wall-clock for both, the speedup (floored), and a byte-identity
    check that the maintained placement equals the full pack's.

``--smoke`` runs a 2 000-job micro-zoo configuration with a conservative
throughput floor for CI; the full run asserts >= 10 000 concurrent jobs at
>= 3 500 jobs/s (>= 10x the PR 3 per-job loop) and a >= 10x repack-cost
reduction.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.api import (DeviceInventory, ReferenceLibrary, TPUPowerModel,
                       VariabilityModel, count_classifier_calls,
                       fleet_job_mix, micro_gemm, micro_idle_burst,
                       micro_spmv_compute, micro_spmv_memory, micro_stencil,
                       simulate, stream_profile_workload, stream_telemetry)
from repro.fleet import FleetCapController, FleetTelemetryMux

SUSTAIN_WINDOW = 50              # samples for the sustained rolling mean
BUDGET_FRACTION = 0.75           # of nameplate: the oversubscription target
GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)


def _sustained(agg: np.ndarray, window: int = SUSTAIN_WINDOW) -> np.ndarray:
    if len(agg) < window:
        return np.array([agg.mean()]) if len(agg) else np.zeros(1)
    kernel = np.ones(window) / window
    return np.convolve(agg, kernel, mode="valid")


def _repack_microbench(scheduler, plans, budget: float):
    """Replay the drained population as a control-plane event stream — one
    admission per plan plus a budget squeeze-and-release — through the
    maintained packer and through a from-scratch ``pack()`` per event (what
    every repack cost before the incremental path).  Both sides produce a
    repack answer after every event — the packer's deferred re-flow is
    forced by the per-event ``stats()`` read, so the comparison stays
    apples-to-apples.  Returns the two wall-clocks and both final
    placements for the byte-identity check."""
    plans = list(plans)
    t0 = time.perf_counter()
    packer = scheduler.packer(budget)
    for plan in plans:
        packer.insert(plan)
        packer.stats()
    packer.set_budget(budget * 0.9)
    packer.stats()
    packer.set_budget(budget)
    t_incremental = time.perf_counter() - t0
    incremental = packer.result()

    t0 = time.perf_counter()
    live = []
    for plan in plans:
        live.append(plan)
        full = scheduler.pack(live, budget)
    scheduler.pack(live, budget * 0.9)
    full = scheduler.pack(live, budget)
    t_full = time.perf_counter() - t0
    return t_incremental, t_full, incremental, full


def run(smoke: bool = False) -> dict:
    if smoke:
        counts = {"tpu-v5e": 4, "tpu-v5p": 2}
        streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
                   micro_idle_burst(), micro_stencil()]
        model = TPUPowerModel()
        lib = ReferenceLibrary(
            (stream_profile_workload(s, model, (0.6, 0.8, 1.0),
                                     model.spec.tdp_w, seed=i,
                                     target_duration=1.0)
             for i, s in enumerate(streams)),
            built_on=model.spec.name)
        jobs = [(streams[i % len(streams)], 32) for i in range(2_000)]
        floor_jobs_per_s = 500.0
        min_concurrent = 2_000
    else:
        counts = {"tpu-v5e": 32, "tpu-v5p": 16, "tpu-v6e": 16}
        lib = reference_library()
        jobs = fleet_job_mix(10_000, seed=11)
        floor_jobs_per_s = 3_500.0
        min_concurrent = 10_000
    floor_admit_jobs_per_s = 3_000.0 if smoke else 5_000.0
    floor_repack_speedup = 5.0 if smoke else 10.0
    target_duration = 0.4

    # zero variability: devices of one model share a power frame, so
    # telemetry and ground truth cache per (workload, chip model)
    inventory = DeviceInventory.generate(counts, VariabilityModel.none(),
                                         seed=7)
    assigned = [(s, chips, inventory[i % len(inventory)])
                for i, (s, chips) in enumerate(jobs)]
    nameplate = sum(chips * dev.nameplate_w for _, chips, dev in assigned)
    budget = BUDGET_FRACTION * nameplate

    # pre-generate each distinct (workload, model) telemetry stream ONCE;
    # chunks are immutable, so every job of that pair shares the arrays
    seeds = {name: 500 + i
             for i, name in enumerate(sorted({s.name for s, _, _ in
                                              assigned}))}
    telemetry = {}
    for stream, _, dev in assigned:
        key = (stream.name, dev.model)
        if key not in telemetry:
            meta, chunks = stream_telemetry(
                stream, 1.0, dev.power_model(), seed=seeds[stream.name],
                target_duration=target_duration, chunk_samples=256)
            telemetry[key] = (meta, list(chunks))

    # best-of-N attempts: the fleet drive is fully deterministic (same
    # streams, same seeds — every attempt lands the identical decisions),
    # so the fastest wall-clock is the engine's throughput and the slower
    # attempts are co-tenant scheduler noise
    attempts = 2 if smoke else 3
    best = None
    for _ in range(attempts):
        fleet = FleetCapController(lib, budget_w=budget,
                                   provision_quantile="p99", repack="tick",
                                   **GATES)
        mux = FleetTelemetryMux()
        t0 = time.perf_counter()
        # bulk admission: the whole fleet lands through ONE validated call
        job_ids = fleet.admit_many(
            dict(device=dev, meta=telemetry[(stream.name, dev.model)][0],
                 chips=chips, job_id=f"j{i:05d}:{stream.name}")
            for i, (stream, chips, dev) in enumerate(assigned))
        t_admit = time.perf_counter() - t0
        for (stream, chips, dev), job_id in zip(assigned, job_ids):
            meta, chunks = telemetry[(stream.name, dev.model)]
            mux.add_job(job_id, meta, chunks, device_id=dev.device_id)
        result = fleet.run(mux)
        elapsed = time.perf_counter() - t0
        if best is None or elapsed < best[0]:
            best = (elapsed, t_admit, fleet, result)
    elapsed, t_admit, fleet, result = best
    jobs_per_s = len(assigned) / elapsed
    admit_jobs_per_s = len(assigned) / t_admit
    drive_repack_s = fleet.repack_s          # incremental path, whole drive

    # repacks must never re-classify: cached JobPlans only
    calls = count_classifier_calls(fleet.clf)
    fleet.set_budget(budget * 0.9)
    fleet.set_budget(budget)
    clf_calls_on_repack = calls["n"]
    final = fleet.repacks[-1]

    # ground truth: one re-simulation per (workload, model, cap) group at
    # the group's decided cap, weighted by its total placed chips
    placed = {p.job_id: p for p in final.placed}
    group_chips: dict[tuple, int] = {}
    for i, (stream, chips, dev) in enumerate(assigned):
        plan = placed.get(f"j{i:05d}:{stream.name}")
        if plan is None:
            continue                       # deferred: draws no power
        key = (stream.name, dev.model, plan.cap)
        group_chips[key] = group_chips.get(key, 0) + plan.chips
    sim_streams = {s.name: s for s, _, _ in assigned}
    sim_models = {dev.model: dev.power_model() for _, _, dev in assigned}
    traces = [n_chips * simulate(sim_streams[name], cap, sim_models[model],
                                 seed=seeds[name],
                                 target_duration=target_duration
                                 ).power_filtered
              for (name, model, cap), n_chips in sorted(group_chips.items())]
    if traces:
        n = max(len(t) for t in traces)
        aggregate = np.sum([np.resize(t, n) for t in traces], axis=0)
    else:
        aggregate = np.zeros(1)
    sustained = _sustained(aggregate)
    violations = int(np.sum(sustained > budget))

    # repack-cost split: maintained packer vs full pack per event over the
    # drained population, plus the byte-identity bar the tentpole promises
    decided_plans = [j.plan for j in fleet.jobs.values()
                     if j.plan is not None]
    t_inc, t_full, inc_res, full_res = _repack_microbench(
        fleet.scheduler, decided_plans, budget)
    repack_speedup = t_full / t_inc if t_inc > 0 else float("inf")
    packs_identical = (
        [p.job_id for p in inc_res.placed]
        == [p.job_id for p in full_res.placed]
        and inc_res.deferred == full_res.deferred)

    engine = fleet.engine
    slot_bytes = sum(h.itemsize * h.shape[1] for h in engine._hist.values())
    out = {
        "config": {
            "smoke": smoke,
            "devices": {m: len(inventory.by_model(m))
                        for m in inventory.models},
            "n_jobs": len(assigned),
            "chunk_samples": 256,
            "budget_w": round(budget, 1),
            "budget_fraction_of_nameplate": BUDGET_FRACTION,
            "engine": "batched",
            "repack": "tick",
            "attempts": attempts,
        },
        "jobs_per_s": round(jobs_per_s, 1),
        "admit_jobs_per_s": round(admit_jobs_per_s, 1),
        "admit_s": round(t_admit, 3),
        "run_s": round(elapsed - t_admit, 3),
        "repack": {
            "events": len(decided_plans) + 2,
            "incremental_s": round(t_inc, 4),
            "full_s": round(t_full, 4),
            "speedup": round(repack_speedup, 1),
            "byte_identical": packs_identical,
            "drive_repack_s": round(drive_repack_s, 4),
        },
        "early_decisions": result.early_decisions,
        "decisions": len(result.decisions),
        "repacks": result.repacks,
        "chunks_dropped": result.chunks_dropped,
        "placed": len(final.placed),
        "deferred": len(final.deferred),
        "planned_power_w": round(final.planned_power_w, 1),
        "headroom_reclaimed_w": round(final.headroom_reclaimed_w, 1),
        "clf_calls_on_repack": clf_calls_on_repack,
        "budget_violations": violations,
        "peak_sustained_w": round(float(sustained.max()), 1),
        "engine_slots": engine.capacity,
        "hist_bytes_per_slot": slot_bytes,
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "fleet_scale.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("fleet_scale_batched", elapsed * 1e6,
         f"jobs={len(assigned)};jobs/s={jobs_per_s:.0f};"
         f"admit/s={admit_jobs_per_s:.0f};repack_x={repack_speedup:.0f};"
         f"violations={violations};clf_on_repack={clf_calls_on_repack}")
    assert len(assigned) >= min_concurrent
    assert len(result.decisions) == len(assigned), (
        f"only {len(result.decisions)}/{len(assigned)} jobs decided")
    assert clf_calls_on_repack == 0, (
        f"re-pack re-classified {clf_calls_on_repack} times")
    assert violations == 0, (
        f"fleet exceeded its power budget in {violations} sustained windows "
        f"(peak {sustained.max():.0f} W vs budget {budget:.0f} W)")
    assert jobs_per_s >= floor_jobs_per_s, (
        f"throughput regression: {jobs_per_s:.0f} jobs/s < floor "
        f"{floor_jobs_per_s:.0f}")
    assert admit_jobs_per_s >= floor_admit_jobs_per_s, (
        f"bulk-admission regression: {admit_jobs_per_s:.0f} jobs/s < floor "
        f"{floor_admit_jobs_per_s:.0f}")
    assert packs_identical, (
        "incremental packer diverged from the full pack on the drained "
        "population")
    assert repack_speedup >= floor_repack_speedup, (
        f"repack-cost regression: incremental path is only "
        f"{repack_speedup:.1f}x cheaper than full packs (floor "
        f"{floor_repack_speedup:.0f}x)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2k-job micro-zoo configuration for CI")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
