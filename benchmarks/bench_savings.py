"""Paper §7.1.3: profiling-time savings of Minos's single-frequency profile
vs a full frequency sweep, across the reference workloads."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.analysis.hardware import FREQ_SWEEP
from repro.core.algorithm1 import profiling_savings


def run() -> dict:
    t0 = time.time()
    refs = reference_library().profiles
    rows = {r.name: round(profiling_savings(r, list(FREQ_SWEEP)), 4)
            for r in refs}
    mean = float(np.mean(list(rows.values())))
    out = {"per_workload": rows, "mean": round(mean, 4),
           "paper_claim": "89-90% for FAISS/Qwen1.5-MoE"}
    with open(os.path.join(RESULTS, "savings.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("profiling_savings_7_1_3", (time.time() - t0) * 1e6,
         f"mean={mean:.3f};min={min(rows.values()):.3f};"
         f"max={max(rows.values()):.3f}")
    return out


if __name__ == "__main__":
    print(run()["mean"])
