"""Chaos-tested recovery: seeded failure injection on an elastic fleet.

A heterogeneous, variability-aware fleet runs a seeded job mix through one
``repro.api.MinosSession`` under a 75%-of-nameplate power budget while the
harness kills, degrades, and restores devices mid-stream (a seeded schedule
— every run replays the same chaos).  Each injected failure must recover by
**migration, never re-classification**: affected jobs are re-planned onto
surviving healthy devices straight from their cached ``CapDecision``
selections (device-portable classification makes the cross-model move
free), and a multi-chip job that loses part of its device span shrinks
through the elastic re-mesh instead.

Emits one ``emit()`` row and writes ``results/chaos.json``:
  * ``recovery_ms``            — wall-clock per injected fail/degrade event
    (migrate + repack), mean and max;
  * ``migrations``             — jobs moved or elastically shrunk;
  * ``classifier_calls_chaos`` — classifier invocations during all
    fail/degrade/restore handling — asserted **0**;
  * ``budget_violations``      — sustained (50-sample rolling mean) samples
    where the re-simulated surviving placement exceeds the budget —
    asserted **0**.

``--smoke`` runs a micro-zoo configuration for CI.
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.api import (DeviceInventory, FleetTelemetryMux, MinosSession,
                       ReferenceLibrary, StragglerMonitor, TPUPowerModel,
                       VariabilityModel, count_classifier_calls,
                       fleet_job_mix, micro_gemm, micro_idle_burst,
                       micro_spmv_compute, micro_spmv_memory, micro_stencil,
                       simulate, stream_profile_workload, stream_telemetry)

SUSTAIN_WINDOW = 50              # samples (~50 ms at 1 kHz) for the rolling mean
BUDGET_FRACTION = 0.75           # of nameplate: the oversubscription target
CHUNK_SAMPLES = 100


def _sustained(agg: np.ndarray, window: int = SUSTAIN_WINDOW) -> np.ndarray:
    if len(agg) < window:
        return np.array([agg.mean()]) if len(agg) else np.zeros(1)
    kernel = np.ones(window) / window
    return np.convolve(agg, kernel, mode="valid")


def _chaos_schedule(total_chunks: int, inventory, assigned, seed: int):
    """Seeded (chunk-index, action, device_id) schedule: kill one loaded
    device a quarter of the way in, degrade another at the midpoint,
    restore the killed one at three quarters, kill a second near the end."""
    rng = np.random.default_rng(seed)
    loaded = sorted({dev.device_id for _, _, dev in assigned})
    victims = [loaded[int(rng.integers(len(loaded)))]]
    rest = [d for d in loaded if d not in victims]
    degraded = rest[int(rng.integers(len(rest)))]
    second = [d for d in rest if d != degraded]
    victims.append(second[int(rng.integers(len(second)))])
    return [
        (int(0.25 * total_chunks), "fail", victims[0]),
        (int(0.50 * total_chunks), "degrade", degraded),
        (int(0.70 * total_chunks), "restore", victims[0]),
        (int(0.80 * total_chunks), "fail", victims[1]),
    ]


def run(smoke: bool = False) -> dict:
    if smoke:
        counts = {"tpu-v5e": 3, "tpu-v5p": 2}
        streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
                   micro_idle_burst(), micro_stencil()]
        model = TPUPowerModel()
        lib = ReferenceLibrary(
            (stream_profile_workload(s, model, (0.6, 0.8, 1.0),
                                     model.spec.tdp_w, seed=i,
                                     target_duration=1.0)
             for i, s in enumerate(streams)),
            built_on=model.spec.name)
        jobs = [(s, 4 * (i % 3 + 1)) for i, s in enumerate(streams)]
        target_duration = 1.0
    else:
        counts = {"tpu-v5e": 6, "tpu-v5p": 3, "tpu-v6e": 3}
        lib = reference_library()
        jobs = fleet_job_mix(16, seed=11)
        target_duration = 2.0

    inventory = DeviceInventory.generate(counts, VariabilityModel(), seed=7)
    assigned = [(s, chips, inventory[i % len(inventory)])
                for i, (s, chips) in enumerate(jobs)]
    nameplate = sum(chips * dev.nameplate_w for _, chips, dev in assigned)
    budget = BUDGET_FRACTION * nameplate

    session = MinosSession(lib, inventory=inventory, budget_w=budget,
                           objective="powercentric", quantile="p99",
                           min_confidence=0.2,
                           stragglers=StragglerMonitor())
    mux = FleetTelemetryMux()
    handles = {}
    for i, (stream, chips, dev) in enumerate(assigned):
        meta, chunks = stream_telemetry(
            stream, 1.0, dev.power_model(), seed=700 + i,
            target_duration=target_duration, chunk_samples=CHUNK_SAMPLES,
            device_id=dev.device_id)
        handle = session.submit(meta, device=dev, chips=chips,
                                job_id=f"j{i:02d}:{stream.name}")
        handles[handle.job_id] = handle
        mux.add_job(handle.job_id, meta, chunks)
    total_chunks = sum(math.ceil(h.meta.n_samples / CHUNK_SAMPLES)
                       for h in handles.values())

    schedule = _chaos_schedule(total_chunks, inventory, assigned, seed=23)
    injected = [dict(at_chunk=at, action=a, device=d) for at, a, d in schedule]
    calls = count_classifier_calls(session.classifier)

    recovery_ms = []
    chaos_calls = 0
    failed_now: set[str] = set()
    t_run = time.perf_counter()
    n = 0
    pending = list(schedule)
    for fchunk in mux:
        while pending and n >= pending[0][0]:
            _, action, device_id = pending.pop(0)
            before = calls["n"]
            t0 = time.perf_counter()
            if action == "fail":
                session.fail_device(device_id)
                mux.drop_device(device_id)     # the wire goes silent too
                failed_now.add(device_id)
            elif action == "degrade":
                session.degrade_device(device_id)
            else:
                session.restore_device(device_id)
                failed_now.discard(device_id)
            dt_ms = (time.perf_counter() - t0) * 1e3
            chaos_calls += calls["n"] - before
            if action in ("fail", "degrade"):
                recovery_ms.append(dt_ms)
        n += 1
        if fchunk.device_id in failed_now:
            continue               # in-flight chunk from dead silicon
        handles[fchunk.job_id].feed(fchunk.chunk)
    for _, action, device_id in pending:       # stream ended first: apply
        before = calls["n"]
        if action == "fail":
            session.fail_device(device_id)
        elif action == "degrade":
            session.degrade_device(device_id)
        else:
            session.restore_device(device_id)
        chaos_calls += calls["n"] - before

    # mid-profile migrants lost their partial trace with their device:
    # restart their profiling runs on the silicon they landed on, then let
    # the session drain + finalize everything
    reprofiled = 0
    for i, (stream, chips, dev) in enumerate(assigned):
        handle = handles[f"j{i:02d}:{stream.name}"]
        if not handle.decided and handle.fraction == 0.0:
            handle.reprofile(stream, seed=900 + i,
                             target_duration=target_duration,
                             chunk_samples=CHUNK_SAMPLES)
            reprofiled += 1
    report = session.run()
    elapsed = time.perf_counter() - t_run

    # no placed job may sit on a currently-failed device
    health = session.device_health
    on_dead = [p.job_id for p in report.schedule.placed
               if health.get(p.device_id) == "failed"]
    assert not on_dead, f"jobs placed on failed devices: {on_dead}"

    # ground truth: re-simulate every placed job at its cap on its FINAL
    # device (migrations included) and check the sustained aggregate
    placed = {p.job_id: p for p in report.schedule.placed}
    traces = []
    for i, (stream, chips, dev) in enumerate(assigned):
        plan = placed.pop(f"j{i:02d}:{stream.name}", None)
        if plan is None:
            continue                       # deferred/stranded: draws no power
        final_dev = inventory.get(plan.device_id)
        tr = simulate(stream, plan.cap, final_dev.power_model(), seed=700 + i,
                      target_duration=target_duration)
        traces.append(plan.chips * tr.power_filtered)
    assert not placed, f"unmatched placed plans: {sorted(placed)}"
    if traces:
        m = max(len(t) for t in traces)
        aggregate = np.sum([np.resize(t, m) for t in traces], axis=0)
    else:
        aggregate = np.zeros(1)
    sustained = _sustained(aggregate)
    violations = int(np.sum(sustained > budget))

    out = {
        "config": {
            "smoke": smoke,
            "devices": {mname: len(inventory.by_model(mname))
                        for mname in inventory.models},
            "n_jobs": len(assigned),
            "budget_w": round(budget, 1),
            "budget_fraction_of_nameplate": BUDGET_FRACTION,
            "provision_quantile": report.quantile,
            "chaos_schedule": injected,
        },
        "recovery_ms": {
            "mean": round(float(np.mean(recovery_ms)), 3),
            "max": round(float(np.max(recovery_ms)), 3),
            "events": [round(r, 3) for r in recovery_ms],
        },
        "failures": report.failures,
        "migrations": report.migrations,
        "events": [{"kind": e.kind, "device": e.device_id, "job": e.job_id,
                    "to": e.to_device_id, "detail": e.detail}
                   for e in report.events],
        "device_health": health,
        "classifier_calls_chaos": chaos_calls,
        "reprofiled_jobs": reprofiled,
        "repacks": report.repacks,
        "placed": len(report.schedule.placed),
        "deferred": len(report.schedule.deferred),
        "planned_power_w": round(report.schedule.planned_power_w, 1),
        "budget_violations": violations,
        "peak_sustained_w": round(float(sustained.max()), 1),
        "elapsed_s": round(elapsed, 3),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "chaos.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("fleet_chaos_recovery", float(np.mean(recovery_ms)) * 1e3,
         f"migrations={report.migrations};violations={violations};"
         f"clf_calls={chaos_calls}")
    assert chaos_calls == 0, (
        f"chaos handling classified {chaos_calls} times; migrations must "
        f"re-plan from cached decisions only")
    assert violations == 0, (
        f"surviving fleet exceeded its power budget in {violations} "
        f"sustained windows (peak {sustained.max():.0f} W vs budget "
        f"{budget:.0f} W)")
    assert report.migrations > 0, "chaos schedule migrated nothing"
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="micro-zoo configuration for CI")
    args = ap.parse_args()
    print(json.dumps(run(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
