"""Crash-recovery: SIGKILL a durable session mid-stream, resume, re-plan.

A child process runs a seeded job mix through a ``MinosSession`` backed by a
durable store (write-ahead journal + snapshots), fails one loaded device
mid-stream, and then **SIGKILLs itself** between chunk feeds — no cleanup,
no flush beyond what the journal already guaranteed.  The parent then calls
``MinosSession.resume`` on the store directory and must get the session
back:

  * **zero classifier calls** during resume (cached decisions and plans are
    adopted from the journal, never recomputed) — asserted;
  * decided jobs keep their caps and placements; mid-profile jobs come back
    flagged ``needs_reprofile`` and restart their runs on their current
    device;
  * after the drain, the surviving placement shows **zero sustained budget
    violations** (50-sample rolling mean over re-simulated ground truth) —
    asserted.

Emits one ``emit()`` row (resume latency) and writes
``results/recovery.json`` plus a copy of the journal at
``results/recovery_journal.jsonl`` for artifact upload.

``--smoke`` runs a shorter micro configuration for CI; ``--child`` is the
internal crash-target entry point.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import signal
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks.common import RESULTS, emit
from repro.api import (DeviceInventory, FleetTelemetryMux, MinosSession,
                       ReferenceLibrary, TPUPowerModel, VariabilityModel,
                       count_classifier_calls, micro_gemm, micro_idle_burst,
                       micro_spmv_compute, micro_spmv_memory, micro_stencil,
                       simulate, stream_profile_workload, stream_telemetry)

SUSTAIN_WINDOW = 50              # samples for the rolling-mean violation test
BUDGET_FRACTION = 0.75           # of nameplate: the oversubscription target
CHUNK_SAMPLES = 100
FAIL_FRACTION = 0.40             # inject the device failure at 40% of chunks
CRASH_FRACTION = 0.55            # SIGKILL at 55% of chunks


def _sustained(agg: np.ndarray, window: int = SUSTAIN_WINDOW) -> np.ndarray:
    if len(agg) < window:
        return np.array([agg.mean()]) if len(agg) else np.zeros(1)
    kernel = np.ones(window) / window
    return np.convolve(agg, kernel, mode="valid")


def _setup(smoke: bool):
    """Deterministic scenario shared by the crash child and the resuming
    parent: same library, inventory, job mix, and budget in both processes."""
    streams = [micro_gemm(), micro_spmv_memory(), micro_spmv_compute(),
               micro_idle_burst(), micro_stencil()]
    target_duration = 1.0 if smoke else 2.0
    model = TPUPowerModel()
    lib = ReferenceLibrary(
        (stream_profile_workload(s, model, (0.6, 0.8, 1.0),
                                 model.spec.tdp_w, seed=i,
                                 target_duration=target_duration)
         for i, s in enumerate(streams)),
        built_on=model.spec.name)
    jobs = [(s, 4 * (i % 3 + 1)) for i, s in enumerate(streams)]
    if not smoke:
        jobs += [(s, 2) for s in streams[:3]]
    inventory = DeviceInventory.generate({"tpu-v5e": 3, "tpu-v5p": 2},
                                         VariabilityModel(), seed=7)
    assigned = [(s, chips, inventory[i % len(inventory)])
                for i, (s, chips) in enumerate(jobs)]
    nameplate = sum(chips * dev.nameplate_w for _, chips, dev in assigned)
    return lib, inventory, assigned, BUDGET_FRACTION * nameplate, \
        target_duration


def _job_id(i: int, stream) -> str:
    return f"j{i:02d}:{stream.name}"


def child(store: str, smoke: bool) -> None:
    """The crash target: run the scenario against a durable store, fail a
    device mid-stream, then SIGKILL self between chunk feeds."""
    lib, inventory, assigned, budget, target_duration = _setup(smoke)
    session = MinosSession(lib, inventory=inventory, budget_w=budget,
                           min_confidence=0.2, store=store)
    mux = FleetTelemetryMux()
    handles = {}
    for i, (stream, chips, dev) in enumerate(assigned):
        meta, chunks = stream_telemetry(
            stream, 1.0, dev.power_model(), seed=700 + i,
            target_duration=target_duration, chunk_samples=CHUNK_SAMPLES,
            device_id=dev.device_id)
        handle = session.submit(meta, device=dev, chips=chips,
                                job_id=_job_id(i, stream))
        handles[handle.job_id] = handle
        mux.add_job(handle.job_id, meta, chunks)
    total = sum(int(np.ceil(h.meta.n_samples / CHUNK_SAMPLES))
                for h in handles.values())
    fail_at, crash_at = int(FAIL_FRACTION * total), int(CRASH_FRACTION * total)
    victim = assigned[0][2].device_id
    failed = False
    for n, fchunk in enumerate(mux):
        if n >= crash_at:
            os.kill(os.getpid(), signal.SIGKILL)   # the crash under test
        if not failed and n >= fail_at:
            session.fail_device(victim)
            mux.drop_device(victim)
            failed = True
        if failed and fchunk.device_id == victim:
            continue
        handles[fchunk.job_id].feed(fchunk.chunk)
    raise AssertionError("stream drained before the scheduled crash")


def run(smoke: bool = False) -> dict:
    store = os.path.join(tempfile.mkdtemp(prefix="minos-recovery-"), "store")

    # -- crash: the child takes SIGKILL mid-stream -----------------------
    proc = subprocess.run(
        [sys.executable, os.path.abspath(__file__), "--child", store]
        + (["--smoke"] if smoke else []))
    assert proc.returncode == -signal.SIGKILL, (
        f"crash child exited {proc.returncode}, expected "
        f"-{int(signal.SIGKILL)} (SIGKILL)")
    assert os.path.exists(os.path.join(store, "journal.jsonl")), \
        "crashed session left no journal behind"

    # -- resume: zero classifier calls, decisions adopted from the journal
    lib, inventory, assigned, budget, target_duration = _setup(smoke)
    classifier = lib.classifier()
    calls = count_classifier_calls(classifier)
    t0 = time.perf_counter()
    session = MinosSession.resume(store, references=classifier)
    resume_ms = (time.perf_counter() - t0) * 1e3
    resume_calls = calls["n"]

    decided = [jid for jid, h in session.jobs.items() if h.decided]
    reprofiled = 0
    for i, (stream, chips, dev) in enumerate(assigned):
        handle = session.jobs[_job_id(i, stream)]
        if not handle.decided:
            # the partial trace died with the process: restart profiling on
            # whatever device the job sits on now
            handle.reprofile(stream, seed=900 + i,
                             target_duration=target_duration,
                             chunk_samples=CHUNK_SAMPLES)
            reprofiled += 1
    report = session.run()
    session.close()

    health = session.device_health
    on_dead = [p.job_id for p in report.schedule.placed
               if health.get(p.device_id) == "failed"]
    assert not on_dead, f"resume placed jobs on failed devices: {on_dead}"

    # ground truth: re-simulate every placed job at its cap on its FINAL
    # device and check the sustained aggregate against the budget
    placed = {p.job_id: p for p in report.schedule.placed}
    traces = []
    for i, (stream, chips, dev) in enumerate(assigned):
        plan = placed.get(_job_id(i, stream))
        if plan is None:
            continue
        final_dev = inventory.get(plan.device_id)
        tr = simulate(stream, plan.cap, final_dev.power_model(),
                      seed=700 + i, target_duration=target_duration)
        traces.append(plan.chips * tr.power_filtered)
    if traces:
        m = max(len(t) for t in traces)
        aggregate = np.sum([np.resize(t, m) for t in traces], axis=0)
    else:
        aggregate = np.zeros(1)
    sustained = _sustained(aggregate)
    violations = int(np.sum(sustained > budget))

    with open(os.path.join(store, "journal.jsonl"), "rb") as f:
        journal_records = sum(1 for _ in f)

    out = {
        "config": {
            "smoke": smoke,
            "devices": {mname: len(inventory.by_model(mname))
                        for mname in inventory.models},
            "n_jobs": len(assigned),
            "budget_w": round(budget, 1),
            "budget_fraction_of_nameplate": BUDGET_FRACTION,
            "fail_fraction": FAIL_FRACTION,
            "crash_fraction": CRASH_FRACTION,
        },
        "resume_latency_ms": round(resume_ms, 3),
        "classifier_calls_resume": resume_calls,
        "journal_records": journal_records,
        "decisions_recovered": len(decided),
        "reprofiled_jobs": reprofiled,
        "migrations": report.migrations,
        "device_health": health,
        "placed": len(report.schedule.placed),
        "deferred": len(report.schedule.deferred),
        "planned_power_w": round(report.schedule.planned_power_w, 1),
        "budget_violations": violations,
        "peak_sustained_w": round(float(sustained.max()), 1),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "recovery.json"), "w") as f:
        json.dump(out, f, indent=1)
    shutil.copyfile(os.path.join(store, "journal.jsonl"),
                    os.path.join(RESULTS, "recovery_journal.jsonl"))
    emit("fleet_crash_recovery", resume_ms * 1e3,
         f"clf_calls={resume_calls};decisions={len(decided)};"
         f"violations={violations}")
    assert resume_calls == 0, (
        f"resume classified {resume_calls} times; recovery must adopt "
        f"journaled decisions without re-classification")
    assert len(decided) > 0, "crash landed before any decision was journaled"
    assert violations == 0, (
        f"recovered fleet exceeded its power budget in {violations} "
        f"sustained windows (peak {sustained.max():.0f} W vs budget "
        f"{budget:.0f} W)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="short micro configuration for CI")
    ap.add_argument("--child", metavar="STORE",
                    help=argparse.SUPPRESS)   # internal crash-target mode
    args = ap.parse_args()
    if args.child:
        child(args.child, smoke=args.smoke)
        return
    print(json.dumps(run(smoke=args.smoke), indent=1))


if __name__ == "__main__":
    main()
