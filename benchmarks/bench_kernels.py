"""Kernel correctness/latency microbench: Pallas (interpret) vs jnp oracle.

On CPU the interpret-mode wall time is NOT a TPU performance proxy; the
benchmark reports correctness (max abs err) and the oracle's wall time as
the reference latency, plus the analytic FLOPs of each configuration.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import RESULTS, emit
from repro.kernels import flash_attention, ref, rmsnorm, spike_hist, ssm_scan


def _time(fn, *args, reps=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e6


def run() -> dict:
    rows = []
    key = jax.random.key(0)
    # flash attention
    for (b, s, H, KV, dh) in [(1, 256, 8, 2, 64), (2, 512, 4, 4, 128)]:
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (b, s, H, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, s, KV, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, s, KV, dh), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=128, block_k=128)
        want = ref.flash_attention_ref(q, k, v, causal=True)
        err = float(jnp.max(jnp.abs(out - want)))
        us = _time(lambda a, bb, c: ref.flash_attention_ref(a, bb, c, True), q, k, v)
        flops = 4.0 * b * s * s * H * dh / 2
        rows.append({"kernel": "flash_attention", "shape": f"b{b}s{s}H{H}kv{KV}d{dh}",
                     "max_abs_err": err, "ref_us": us, "flops": flops})
    # ssm scan
    for (b, s, di, ds) in [(2, 256, 256, 16)]:
        ks = jax.random.split(key, 6)
        x = jax.random.normal(ks[0], (b, s, di)) * 0.5
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, di)) * 0.2 - 1)
        A = -jnp.exp(jax.random.normal(ks[2], (di, ds)) * 0.3)
        B = jax.random.normal(ks[3], (b, s, ds)) * 0.5
        C = jax.random.normal(ks[4], (b, s, ds)) * 0.5
        D = jnp.ones((di,))
        y = ssm_scan(x, dt, A, B, C, D)
        want, _ = ref.ssm_scan_ref(x, dt, A, B, C, D)
        err = float(jnp.max(jnp.abs(y - want)))
        us = _time(lambda *a: ref.ssm_scan_ref(*a)[0], x, dt, A, B, C, D)
        rows.append({"kernel": "ssm_scan", "shape": f"b{b}s{s}di{di}ds{ds}",
                     "max_abs_err": err, "ref_us": us,
                     "flops": 9.0 * b * s * di * ds})
    # spike hist
    p = jax.random.uniform(jax.random.key(3), (100_000,), jnp.float32, 0, 2.2) * 200
    v1 = spike_hist(p, 200.0, n_bins=15)
    counts = ref.spike_hist_ref(p / 200.0, 15)
    err = float(jnp.max(jnp.abs(v1 - counts / jnp.sum(counts))))
    us = _time(lambda a: ref.spike_hist_ref(a, 15), p / 200.0)
    rows.append({"kernel": "spike_hist", "shape": "n100k", "max_abs_err": err,
                 "ref_us": us, "flops": 2.0 * len(p) * 15})
    # rmsnorm
    x = jax.random.normal(jax.random.key(4), (1024, 1024), jnp.bfloat16)
    sc = jnp.ones((1024,))
    err = float(jnp.max(jnp.abs(
        rmsnorm(x, sc).astype(jnp.float32) -
        ref.rmsnorm_ref(x, sc).astype(jnp.float32))))
    us = _time(lambda a, b: ref.rmsnorm_ref(a, b), x, sc)
    rows.append({"kernel": "rmsnorm", "shape": "1024x1024", "max_abs_err": err,
                 "ref_us": us, "flops": 4.0 * 1024 * 1024})

    with open(os.path.join(RESULTS, "kernels.json"), "w") as f:
        json.dump(rows, f, indent=1)
    worst = max(rows, key=lambda r: r["max_abs_err"])
    for r in rows:
        emit(f"kernel_{r['kernel']}_{r['shape']}", r["ref_us"],
             f"max_abs_err={r['max_abs_err']:.2e}")
    return {"rows": rows, "worst": worst}


if __name__ == "__main__":
    print(run()["worst"])
