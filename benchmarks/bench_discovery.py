"""Online class discovery: novel production traffic grows the library.

The scenario mirrors the discovery subsystem's acceptance contract
(ISSUE PR 9).  A reference library is built from the micro zoo; the
``novel_streams`` families (encoder-decoder, SSM, MoE, hybrid prefills —
deliberately absent from the library) then arrive as production jobs:

  * **baseline** — in-library jobs: cap agreement against full-profile
    ground truth (``truth_selection``) and mean decided fraction;
  * **novel_before** — the novel families against the shipped library:
    the same metrics, pre-discovery;
  * **discovery** — the same novel traffic quarantined (low margin
    confidence), re-clustered, shadow-evaluated, and promoted; the live
    fleet classifier is spied across the swap — **zero calls** asserted;
  * **novel_after** — fresh arrivals of the same families against the
    promoted library: cap agreement must be within noise of the
    in-library baseline, and the arrivals must classify to the
    discovered classes;
  * **resume** — the promotion replayed from the durable store with zero
    classifier queries;
  * **discovery-off** — a session without the ``discovery`` key is
    byte-identical run-to-run, and a quarantine-only discovery session
    changes none of its decisions (inert-by-default) — asserted.

Writes ``results/discovery.json``; ``--smoke`` runs 2 novel families
with shorter profiles for CI.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time

from benchmarks.common import RESULTS, emit
from repro.api import (MinosSession, ReferenceLibrary, TPUPowerModel,
                       count_classifier_calls, micro_gemm, micro_idle_burst,
                       micro_spmv_compute, micro_spmv_memory, micro_stencil,
                       novel_streams, resolve_objective,
                       stream_profile_workload, stream_profiler,
                       stream_telemetry, to_json, truth_selection)

GATES = dict(min_confidence=0.2, min_fraction=0.1, min_spike_samples=50)
# margin confidence measures ambiguity, not wrongness: a novel family can
# match an existing class decisively-but-wrongly at ~0.7-0.9, so the
# quarantine threshold sits above that band
DISCOVERY = {"quarantine_below": 0.9, "min_cluster": 3,
             "recluster_every": 1000, "promote_agreement": 0.5,
             "cluster_distance": 0.5}
FREQS = (0.6, 0.8, 1.0)
SEEDS_PER_FAMILY = 3             # arrivals per novel family (>= min_cluster)


def _setup(smoke: bool):
    model = TPUPowerModel()
    target_duration = 0.5 if smoke else 1.0
    library_streams = [micro_gemm(), micro_spmv_memory(),
                       micro_spmv_compute(), micro_idle_burst(),
                       micro_stencil()]
    novel = novel_streams()[:2 if smoke else 4]
    lib = ReferenceLibrary(
        (stream_profile_workload(s, model, FREQS, model.spec.tdp_w, seed=i,
                                 target_duration=target_duration)
         for i, s in enumerate(library_streams)),
        built_on=model.spec.name)
    # full-profile ground truth for the novel families: what a production
    # profiling run would measure, and what the shadow evaluator scores
    # candidates against
    truth = {s.name: stream_profile_workload(
        s, model, FREQS, model.spec.tdp_w, seed=50 + i,
        target_duration=target_duration)
        for i, s in enumerate(novel)}
    return model, lib, library_streams, novel, truth, target_duration


def _submit_all(session, streams, model, seeds, target_duration, chips=2):
    """Run one job per (stream, seed) pair; returns the decided handles."""
    handles = []
    for i, stream in enumerate(streams):
        for j in seeds:
            meta = stream_telemetry(stream, 1.0, model,
                                    seed=1000 * (i + 1) + j,
                                    target_duration=target_duration)
            h = session.submit(meta, chips=chips)
            h.run()
            handles.append(h)
    return handles


def _score(handles, truth_by_name, objective) -> dict:
    """Cap agreement vs full-profile ground truth + decision stats."""
    hits, fracs, confs = 0, [], []
    for h in handles:
        d = h.decision()
        truth_cap = objective.cap(truth_selection(
            truth_by_name[h.meta.name], d.selection.bin_size))
        hits += int(d.cap == truth_cap)
        fracs.append(d.fraction)
        confs.append(d.confidence)
    n = len(handles)
    return {"n_jobs": n,
            "cap_agreement": round(hits / n, 4) if n else 0.0,
            "mean_fraction": round(sum(fracs) / n, 4) if n else 0.0,
            "mean_confidence": round(sum(confs) / n, 4) if n else 0.0}


def _decisions(handles) -> list[tuple]:
    return [(d.target, d.cap, d.early, round(d.fraction, 6))
            for d in (h.decision() for h in handles)]


def run(smoke: bool = False) -> dict:
    model, lib, library_streams, novel, truth, target_duration = _setup(smoke)
    objective = resolve_objective("powercentric")
    seeds = range(SEEDS_PER_FAMILY)
    truth_in_library = {p.name: p for p in lib}

    # -- baseline: in-library traffic ------------------------------------
    plain = MinosSession(lib, **GATES)
    baseline = _score(_submit_all(plain, library_streams, model, seeds,
                                  target_duration), truth_in_library,
                      objective)

    # -- novel families against the shipped library ----------------------
    before_session = MinosSession(lib, **GATES)
    novel_before = _score(_submit_all(before_session, novel, model, seeds,
                                      target_duration), truth, objective)

    # -- the discovery loop, durable, with the live classifier spied -----
    store = os.path.join(tempfile.mkdtemp(prefix="minos-discovery-"),
                         "store")
    session = MinosSession(lib, store=store, discovery=DISCOVERY, **GATES)
    _submit_all(session, novel, model, seeds, target_duration)
    quarantined = len(session.discovery.pool)
    session.discovery.profiler = stream_profiler(
        novel, model, FREQS, model.spec.tdp_w,
        target_duration=target_duration)
    live_calls = count_classifier_calls(session._fleet.clf)
    t0 = time.perf_counter()
    promo = session.discover(force=True)
    swap_ms = (time.perf_counter() - t0) * 1e3
    swap_calls = live_calls["n"]
    promoted = promo["classes"] if promo else []

    # -- fresh arrivals of the same families, post-promotion -------------
    after_handles = _submit_all(session, novel, model,
                                [100 + s for s in seeds], target_duration)
    novel_after = _score(after_handles, truth, objective)
    absorbed = sum(1 for h in after_handles
                   if h.decision().selection.power_neighbor in promoted)

    # -- crash-resume across the version bump: zero classifier queries ---
    # every classifier ANY library mints during resume is spied: discovery
    # resume rebuilds versioned libraries, so the spy must cover them all
    session.close()
    spies = []
    orig_classifier = ReferenceLibrary.classifier

    def spied_classifier(self, *a, **k):
        clf = orig_classifier(self, *a, **k)
        spies.append(count_classifier_calls(clf))
        return clf

    ReferenceLibrary.classifier = spied_classifier
    try:
        t0 = time.perf_counter()
        resumed = MinosSession.resume(store, references=lib)
        resume_ms = (time.perf_counter() - t0) * 1e3
    finally:
        ReferenceLibrary.classifier = orig_classifier
    resume_calls = sum(s["n"] for s in spies)
    resumed_version = (resumed.discovery.version
                       if resumed.discovery else 1)
    resumed.close()
    shutil.rmtree(os.path.dirname(store), ignore_errors=True)

    # -- inert-by-default: no discovery key => byte-identical ------------
    def _plain_report():
        s = MinosSession(lib, **GATES)
        handles = _submit_all(s, library_streams, model, seeds,
                              target_duration)
        return to_json(s.report()), _decisions(handles)

    rep_a, dec_a = _plain_report()
    rep_b, dec_b = _plain_report()
    quarantine_only = MinosSession(lib, discovery=DISCOVERY, **GATES)
    dec_c = _decisions(_submit_all(quarantine_only, library_streams, model,
                                   seeds, target_duration))
    discovery_off_identical = (rep_a == rep_b and dec_a == dec_b
                               and dec_a == dec_c)

    out = {
        "config": {"smoke": smoke, "novel_families": [s.name for s in novel],
                   "seeds_per_family": SEEDS_PER_FAMILY,
                   "discovery": DISCOVERY},
        "baseline": baseline,
        "novel_before": novel_before,
        "novel_after": novel_after,
        "quarantined": quarantined,
        "promoted": promoted,
        "absorbed_by_promoted": absorbed,
        "swap_latency_ms": round(swap_ms, 3),
        "swap_classifier_calls": swap_calls,
        "resume_latency_ms": round(resume_ms, 3),
        "resume_classifier_calls": resume_calls,
        "resumed_library_version": resumed_version,
        "discovery_off_identical": bool(discovery_off_identical),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "discovery.json"), "w") as f:
        json.dump(out, f, indent=1)
    emit("class_discovery", swap_ms * 1e3,
         f"promoted={len(promoted)};agree_after="
         f"{novel_after['cap_agreement']};swap_calls={swap_calls}")
    assert promoted, (
        f"no class promoted from {quarantined} quarantined novel arrivals")
    assert swap_calls == 0, (
        f"library swap made {swap_calls} live classifier calls; adoption "
        f"must be zero-call")
    assert resume_calls == 0, (
        f"resume across the version bump made {resume_calls} classifier "
        f"calls; discovery records must replay without re-classification")
    assert resumed_version >= 2, (
        f"resume came back at library version {resumed_version}; the "
        f"journaled promotion was not re-adopted")
    assert discovery_off_identical, (
        "a session without the discovery key is not byte-identical "
        "run-to-run, or a quarantine-only session changed decisions")
    assert novel_after["cap_agreement"] >= baseline["cap_agreement"] - 0.25, (
        f"post-promotion novel agreement {novel_after['cap_agreement']} "
        f"fell more than 0.25 below the in-library baseline "
        f"{baseline['cap_agreement']}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="2 novel families, shorter profiles (CI)")
    args = ap.parse_args()
    out = run(smoke=args.smoke)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
