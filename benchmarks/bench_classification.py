"""Paper Fig. 3 (power dendrogram) + Fig. 4 (utilization K-Means) + Table 1
class columns."""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import RESULTS, emit, reference_library
from repro.core.clustering import dendrogram_order


def _ascii_dendrogram(names, Z, labels) -> str:
    order = dendrogram_order(Z)
    lines = ["power-spike dendrogram (ward/cosine), leaves in merge order:"]
    for i in order:
        lines.append(f"  [{labels[i]}] {names[i]}")
    return "\n".join(lines)


def run() -> dict:
    t0 = time.time()
    lib = reference_library()
    refs = lib.profiles
    clf = lib.classifier()
    names = lib.names

    Z = clf.power_linkage()
    power_labels = clf.power_classes(k=3)
    # interpret clusters: order by mean p90 -> Low / Mixed / High
    means = {}
    for c in set(power_labels):
        members = [refs[i] for i in range(len(refs)) if power_labels[i] == c]
        means[c] = np.mean([m.p_quantile(90) for m in members])
    rank = {c: i for i, c in enumerate(sorted(means, key=means.get))}
    tags = ["Low-spike", "Mixed", "High-spike"]
    power_class = {n: tags[rank[c]] for n, c in zip(names, power_labels)}

    util_labels, centers, k_best, sil_scores = clf.util_classes()
    cmeans = {c: centers[c][1] - centers[c][0] for c in range(k_best)}  # sm - dram
    crank = {c: i for i, c in enumerate(sorted(cmeans, key=cmeans.get))}
    utags = ["M", "H", "C"] if k_best == 3 else [f"U{i}" for i in range(k_best)]
    util_class = {n: utags[crank[c]] if k_best == 3 else utags[crank[c]]
                  for n, c in zip(names, util_labels)}

    rows = []
    for r in refs:
        rows.append({
            "workload": r.name, "domain": r.domain,
            "pwr_class": power_class[r.name], "util_class": util_class[r.name],
            "p90": round(r.p_quantile(90), 3), "mean": round(r.mean_power, 3),
            "sm_util": round(r.sm_util, 3), "dram_util": round(r.dram_util, 3),
        })
    out = {
        "table1": rows,
        "silhouette_by_k": {str(k): round(v, 4) for k, v in (sil_scores or {}).items()},
        "k_best": k_best,
        "dendrogram": _ascii_dendrogram(names, Z, [power_class[n][0] for n in names]),
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "classification.json"), "w") as f:
        json.dump(out, f, indent=1)

    n_classes = len(set(power_class.values()))
    emit("classification_fig3_fig4", (time.time() - t0) * 1e6,
         f"pwr_classes={n_classes};k_util={k_best};"
         f"sil={max((sil_scores or {1: 0}).values()):.2f}")
    return out


if __name__ == "__main__":
    o = run()
    print(o["dendrogram"])
    for r in o["table1"]:
        print(r)
