"""Shared benchmark infrastructure: the cached reference library and the
hold-one-out protocol helpers (paper §7.2).

``reference_library`` returns a ``repro.pipeline.ReferenceLibrary``: on a
warm start the fingerprinted spike-matrix cache under
``results/reference_store/`` is adopted, so ``lib.classifier()`` skips
re-histogramming all 28 reference traces at every benchmark process start.
"""
from __future__ import annotations

import os
import time

from repro.core import MinosClassifier, WorkloadProfile
from repro.core.algorithm1 import (cap_perf_centric, cap_power_centric,
                                   POWER_BOUND)
from repro.pipeline import ReferenceLibrary, build_reference_library
from repro.telemetry import TPUPowerModel

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results")
STORE = os.path.join(RESULTS, "reference_store")


def reference_library(rebuild: bool = False) -> ReferenceLibrary:
    os.makedirs(RESULTS, exist_ok=True)
    if not rebuild and os.path.exists(os.path.join(STORE, "profiles.json")):
        lib = ReferenceLibrary.load(STORE)
        # backfill provenance on pre-fleet stores: this function only ever
        # builds on the nominal v5e model, so a missing built_on is v5e
        if not lib.built_on:
            lib.built_on = TPUPowerModel().spec.name
        return lib
    t0 = time.time()
    lib = build_reference_library(TPUPowerModel(), target_duration=3.0)
    lib.save(STORE)
    print(f"# built reference library: {len(lib)} profiles "
          f"in {time.time() - t0:.1f}s")
    return lib


def unique_workloads(refs) -> list[WorkloadProfile]:
    """One profile per workload for hold-one-out (paper: the largest input;
    here: the train cell for each arch, plus every microbenchmark)."""
    out = []
    seen = set()
    for r in refs:
        if ":" in r.name:
            arch, shape = r.name.split(":")
            if shape != "train_4k" or arch in seen:
                continue
            seen.add(arch)
        out.append(r)
    return out


def unique_library(lib: ReferenceLibrary) -> ReferenceLibrary:
    """The hold-one-out subset as a sub-library: cached spike-matrix rows are
    carried over, so ``.classifier()`` stays warm-started."""
    keep = {r.name for r in unique_workloads(lib.profiles)}
    return lib.subset(lambda p: p.name in keep)


def holdout_neighbors(clf: MinosClassifier, targets: list[WorkloadProfile],
                      bin_size: float | None = None):
    """Hold-one-out neighbor lookup for a whole target batch at once.

    Self-exclusion by workload name is built into the classifier's batched
    APIs, so this is two distance-matrix ops total; returns the two aligned
    lists of (neighbor, distance): power (cosine) and utilization
    (Euclidean).
    """
    return (clf.power_neighbors(targets, bin_size=bin_size),
            clf.util_neighbors(targets))


def nearest_freq(profile: WorkloadProfile, f: float) -> float:
    return min(profile.scaling, key=lambda x: abs(x - f))


def degradation(profile: WorkloadProfile, f: float) -> float:
    base = profile.scaling[max(profile.scaling)].exec_time
    return profile.scaling[nearest_freq(profile, f)].exec_time / base - 1.0


def holdout_power_error(target: WorkloadProfile, neighbor: WorkloadProfile,
                        quantile: str = "p90") -> tuple[float, float, float]:
    """(abs prediction error, selected cap, observed value) for PowerCentric."""
    f = cap_power_centric(neighbor, POWER_BOUND, quantile)
    pred = getattr(neighbor.scaling[nearest_freq(neighbor, f)], quantile)
    obs = getattr(target.scaling[nearest_freq(target, f)], quantile)
    return abs(obs - pred), f, obs


def holdout_perf_error(target: WorkloadProfile, neighbor: WorkloadProfile
                       ) -> tuple[float, float, float]:
    f = cap_perf_centric(neighbor)
    pred = degradation(neighbor, f)
    obs = degradation(target, f)
    return abs(obs - pred), f, obs


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py output contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")
