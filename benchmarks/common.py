"""Shared benchmark infrastructure: the cached reference library and the
hold-one-out protocol helpers (paper §7.2)."""
from __future__ import annotations

import os
import time

import numpy as np

from repro.analysis.hardware import FREQ_SWEEP
from repro.core import MinosClassifier, WorkloadProfile
from repro.core.algorithm1 import (cap_perf_centric, cap_power_centric,
                                   POWER_BOUND)
from repro.core.reference_store import load_profiles, save_profiles
from repro.telemetry import TPUPowerModel, build_reference_set

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
RESULTS = os.path.join(REPO, "results")
STORE = os.path.join(RESULTS, "reference_store")


def reference_library(rebuild: bool = False) -> list[WorkloadProfile]:
    os.makedirs(RESULTS, exist_ok=True)
    if not rebuild and os.path.exists(os.path.join(STORE, "profiles.json")):
        return load_profiles(STORE)
    t0 = time.time()
    refs = build_reference_set(TPUPowerModel(), target_duration=3.0)
    save_profiles(refs, STORE)
    print(f"# built reference library: {len(refs)} profiles "
          f"in {time.time() - t0:.1f}s")
    return refs


def unique_workloads(refs: list[WorkloadProfile]) -> list[WorkloadProfile]:
    """One profile per workload for hold-one-out (paper: the largest input;
    here: the train cell for each arch, plus every microbenchmark)."""
    out = []
    seen = set()
    for r in refs:
        if ":" in r.name:
            arch, shape = r.name.split(":")
            if shape != "train_4k" or arch in seen:
                continue
            seen.add(arch)
        out.append(r)
    return out


def holdout_neighbors(clf: MinosClassifier, targets: list[WorkloadProfile],
                      bin_size: float | None = None):
    """Hold-one-out neighbor lookup for a whole target batch at once.

    Self-exclusion by workload name is built into the classifier's batched
    APIs, so this is two distance-matrix ops total; returns the two aligned
    lists of (neighbor, distance): power (cosine) and utilization
    (Euclidean).
    """
    return (clf.power_neighbors(targets, bin_size=bin_size),
            clf.util_neighbors(targets))


def nearest_freq(profile: WorkloadProfile, f: float) -> float:
    return min(profile.scaling, key=lambda x: abs(x - f))


def degradation(profile: WorkloadProfile, f: float) -> float:
    base = profile.scaling[max(profile.scaling)].exec_time
    return profile.scaling[nearest_freq(profile, f)].exec_time / base - 1.0


def holdout_power_error(target: WorkloadProfile, neighbor: WorkloadProfile,
                        quantile: str = "p90") -> tuple[float, float, float]:
    """(abs prediction error, selected cap, observed value) for PowerCentric."""
    f = cap_power_centric(neighbor, POWER_BOUND, quantile)
    pred = getattr(neighbor.scaling[nearest_freq(neighbor, f)], quantile)
    obs = getattr(target.scaling[nearest_freq(target, f)], quantile)
    return abs(obs - pred), f, obs


def holdout_perf_error(target: WorkloadProfile, neighbor: WorkloadProfile
                       ) -> tuple[float, float, float]:
    f = cap_perf_centric(neighbor)
    pred = degradation(neighbor, f)
    obs = degradation(target, f)
    return abs(obs - pred), f, obs


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py output contract: ``name,us_per_call,derived``."""
    print(f"{name},{us_per_call:.1f},{derived}")
