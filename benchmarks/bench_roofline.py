"""Assignment §Roofline: aggregate the dry-run JSONs into the per-cell
three-term roofline table."""
from __future__ import annotations

import glob
import json
import os
import time

from benchmarks.common import RESULTS, emit


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(RESULTS, "dryrun", mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def _rebuild(c: dict) -> "object":
    """Recompute the roofline report from the stored raw per-device costs
    (keeps the table in sync with analysis/roofline.py without recompiling)."""
    from repro.analysis.hlo import Cost
    from repro.analysis.roofline import build_report
    from repro.configs import ARCHS, SHAPES
    r = c["roofline"]
    cost = Cost(flops=r["flops"], hbm_bytes=r["hbm_bytes"],
                hbm_bytes_min=r.get("hbm_bytes_min", r["hbm_bytes"]),
                coll_bytes=dict(r["coll_bytes"]),
                unresolved_loops=r.get("unresolved_loops", 0))
    return build_report(cost, ARCHS[c["arch"]], SHAPES[c["shape"]],
                        c["mesh"], c["n_chips"])


def table(mesh: str = "single") -> list[dict]:
    rows = []
    for c in load_cells(mesh):
        if c["status"] != "OK":
            rows.append({"arch": c["arch"], "shape": c["shape"],
                         "status": c["status"],
                         "reason": c.get("reason", "")[:60]})
            continue
        rep = _rebuild(c)
        rows.append({
            "arch": c["arch"], "shape": c["shape"], "status": "OK",
            "t_compute_ms": round(rep.t_compute * 1e3, 2),
            "t_memory_ms": round(rep.t_memory * 1e3, 2),
            "t_collective_ms": round(rep.t_collective * 1e3, 2),
            "dominant": rep.dominant,
            "useful_ratio": round(rep.useful_ratio, 3),
            "roofline_frac": round(rep.roofline_fraction, 4),
            "temp_gib": round(c["memory"]["temp_bytes"] / 2**30, 2),
            "args_gib": round(c["memory"]["args_bytes"] / 2**30, 2),
        })
    return rows


def run() -> dict:
    t0 = time.time()
    out = {}
    for mesh in ("single", "multi"):
        out[mesh] = table(mesh)
    with open(os.path.join(RESULTS, "roofline_table.json"), "w") as f:
        json.dump(out, f, indent=1)
    ok = [r for r in out["single"] if r.get("status") == "OK"]
    skip = [r for r in out["single"] if r.get("status") == "SKIP"]
    fail = [r for r in out["single"] if r.get("status") == "FAIL"]
    worst = min(ok, key=lambda r: r["roofline_frac"]) if ok else {}
    best = max(ok, key=lambda r: r["roofline_frac"]) if ok else {}
    emit("roofline_table", (time.time() - t0) * 1e6,
         f"ok={len(ok)};skip={len(skip)};fail={len(fail)};"
         f"worst={worst.get('arch','')}:{worst.get('shape','')}="
         f"{worst.get('roofline_frac', 0)};"
         f"best={best.get('arch','')}:{best.get('shape','')}="
         f"{best.get('roofline_frac', 0)}")
    return out


if __name__ == "__main__":
    o = run()
    hdr = f"{'arch':24s}{'shape':13s}{'t_comp':>9s}{'t_mem':>9s}{'t_coll':>9s}  {'dom':10s}{'useful':>7s}{'frac':>7s}"
    print(hdr)
    for r in o["single"]:
        if r.get("status") != "OK":
            print(f"{r['arch']:24s}{r['shape']:13s}  {r['status']}: {r.get('reason','')}")
            continue
        print(f"{r['arch']:24s}{r['shape']:13s}{r['t_compute_ms']:9.1f}"
              f"{r['t_memory_ms']:9.1f}{r['t_collective_ms']:9.1f}  "
              f"{r['dominant']:10s}{r['useful_ratio']:7.2f}{r['roofline_frac']:7.3f}")
